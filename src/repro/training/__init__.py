"""Training substrate: optimizers, train step, checkpointing, data."""
from .checkpoint import AsyncCheckpointer, latest_step, restore, save
from .data import DataConfig, batch_iterator, make_batch
from .optimizer import (OptimizerConfig, adafactor_init, adafactor_update,
                        adamw_init, adamw_update, global_norm, lr_at,
                        opt_init, opt_update)
from .train_step import (TrainConfig, init_train_state,
                         make_sharded_train_step, make_train_step)
