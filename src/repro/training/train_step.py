"""Train step: loss -> grad -> optimizer, with remat and microbatching.

``make_train_step`` returns a pure function suitable for jax.jit with
in/out shardings from repro.dist.sharding.  Remat policy wraps the
super-block scan body (configured through jax.checkpoint around loss_fn).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import loss_fn
from .optimizer import OptimizerConfig, opt_init, opt_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    remat: str = "full"              # full | dots | none
    microbatches: int = 1            # sequential grad accumulation
    skip_masked_chunks: bool = False # halve causal-attention FLOPs


def _remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable


def make_loss(cfg: ModelConfig, train: TrainConfig) -> Callable:
    base = functools.partial(loss_fn, cfg,
                             skip_masked_chunks=train.skip_masked_chunks)
    if train.remat != "none":
        base = jax.checkpoint(base, policy=_remat_policy(train.remat),
                              static_argnums=())
    return base


def make_train_step(cfg: ModelConfig, train: TrainConfig) -> Callable:
    loss = make_loss(cfg, train)
    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if train.microbatches > 1:
            mb = train.microbatches
            B = batch["tokens"].shape[0]
            assert B % mb == 0, (B, mb)
            split = {k: v.reshape(mb, B // mb, *v.shape[1:])
                     for k, v in batch.items()}

            def micro(acc, sub):
                (l, m), g = grad_fn(params, sub)
                g_acc, l_acc = acc
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / mb, g_acc, g)
                return (g_acc, l_acc + l / mb), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_val), _ = jax.lax.scan(micro,
                                                (g0, jnp.zeros((), jnp.float32)),
                                                split)
            metrics = {"ce": loss_val}
        else:
            (loss_val, metrics), grads = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = opt_update(
            train.optimizer, grads, opt_state, params)
        out_metrics = {"loss": loss_val, **metrics, **opt_metrics}
        return new_params, new_opt, out_metrics

    return train_step


def init_train_state(cfg: ModelConfig, train: TrainConfig, params):
    return opt_init(train.optimizer, params)


def make_sharded_train_step(cfg: ModelConfig, train: TrainConfig, mesh,
                            rules=None, donate: bool = True):
    """jit-compiled train step with in/out shardings derived from the
    distribution layer's logical-axis rules.

    Returns ``(step_fn, params_sh, opt_sh)`` — the shardings are also what
    ``init``/``opt_init`` outputs should be placed with (see launch.train).
    """
    # function-level import: repro.dist.sharding reaches back into
    # repro.training.optimizer for the Adafactor factoring predicate
    from ..dist.sharding import (TRAIN_RULES, opt_state_shardings,
                                 tree_shardings)
    from ..models.common import abstract_shapes, logical_axes
    from ..models.model import param_specs

    rules = rules or TRAIN_RULES
    specs = param_specs(cfg)
    params_abs = abstract_shapes(specs, cfg.param_dtype)
    params_axes = logical_axes(specs)
    params_sh = tree_shardings(params_abs, params_axes, rules, mesh)
    opt_sh = opt_state_shardings(train.optimizer, params_abs, params_axes,
                                 params_sh, rules, mesh)
    step = jax.jit(make_train_step(cfg, train),
                   in_shardings=(params_sh, opt_sh, None),
                   out_shardings=(params_sh, opt_sh, None),
                   donate_argnums=(0, 1) if donate else ())
    return step, params_sh, opt_sh
