"""Synthetic token data pipeline with deterministic, resumable cursors.

Produces language-modeling batches (tokens, shifted labels) from a seeded
generator; the cursor (step index) is part of the checkpoint so restarts
resume on the exact batch they left off (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0
    # synthetic structure: mixture of ngram-ish repeats so the loss can fall
    repeat_prob: float = 0.6


def make_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Deterministic batch for a given step (resume == same stream)."""
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2 ** 31))
    B, S = cfg.batch_size, cfg.seq_len
    base = rng.randint(0, cfg.vocab_size, size=(B, S + 1))
    # inject learnable structure: with prob repeat_prob, token t = token t-k
    for k in (2, 3):
        mask = rng.rand(B, S + 1) < (cfg.repeat_prob / 2)
        mask[:, :k] = False
        idx = np.where(mask)
        base[idx[0], idx[1]] = base[idx[0], idx[1] - k]
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


def batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
