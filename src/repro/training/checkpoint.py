"""Sharded checkpointing with atomic commit and restart support.

Layout (one directory per step):
    <dir>/step_000120.tmp/...   (write)
    <dir>/step_000120/          (atomic rename on success)
        index.msgpack           tree structure + shapes/dtypes + metadata
        arr_00000.npy ...       one file per leaf (np.save)

Writes can run on a background thread (async checkpointing) so the train
loop does not stall; ``wait()`` joins before the next save.  Restore picks
the newest complete step directory — interrupted writes are invisible
because of the rename commit.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(directory: str, step: int, tree, metadata: Optional[Dict] = None
         ) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten_with_paths(tree)
    index = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "metadata": metadata or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        path = os.path.join(tmp, f"arr_{i:05d}.npy")
        np.save(path, arr)
        index["leaves"].append({"dtype": str(arr.dtype),
                                "shape": list(arr.shape)})
    with open(os.path.join(tmp, "index.msgpack"), "wb") as f:
        f.write(msgpack.packb(index))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "index.msgpack")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int], tree_template
            ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_template`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "index.msgpack"), "rb") as f:
        index = msgpack.unpackb(f.read())
    leaves_t, treedef = _flatten_with_paths(tree_template)
    assert index["num_leaves"] == len(leaves_t), \
        f"leaf count mismatch: ckpt {index['num_leaves']} vs template {len(leaves_t)}"
    out = []
    for i, (meta, tmpl) in enumerate(zip(index["leaves"], leaves_t)):
        arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
        if arr.dtype.kind == "V":
            # numpy round-trips ml_dtypes (bfloat16 etc.) as raw void;
            # view back using the recorded dtype name
            import ml_dtypes
            try:
                arr = arr.view(np.dtype(meta["dtype"]))
            except TypeError:
                arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        expect = tuple(tmpl.shape) if hasattr(tmpl, "shape") else None
        if expect is not None and tuple(arr.shape) != expect:
            raise ValueError(f"leaf {i}: shape {arr.shape} != {expect}")
        dtype = tmpl.dtype if hasattr(tmpl, "dtype") else arr.dtype
        out.append(jnp.asarray(arr, dtype=dtype))
    return jax.tree.unflatten(treedef, out), step, index["metadata"]


class AsyncCheckpointer:
    """Background-thread checkpoint writer (overlap with training)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save_async(self, step: int, tree, metadata: Optional[Dict] = None):
        self.wait()
        # device_get on the caller thread (arrays may be donated next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            try:
                save(self.directory, step, host_tree, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
