"""Optimizers in pure JAX: AdamW and Adafactor (factored second moment).

Adafactor is the default for >=30B-parameter archs — full Adam state
(8 bytes/param fp32 m+v) does not fit a 16 GB/chip v5e pod for the 236B/398B
assigned configs, while Adafactor's row/col factored second moment is
~O(rows+cols) per matrix (DESIGN.md §5).  Both support optional optimizer-
state dtype control and global-norm clipping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128
    state_dtype: str = "float32"     # float32 | bfloat16 (for adamw m/v)


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(cfg: OptimizerConfig, params):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moment
# ---------------------------------------------------------------------------

def _factored(shape, min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(cfg: OptimizerConfig, params):
    # state is a flat LIST aligned with jax.tree.leaves(params) — nesting it
    # into the param tree would make the factored/{v} dicts ambiguous with
    # param dicts that contain a "v" key (attention blocks do).
    def state_for(p):
        if _factored(p.shape, cfg.min_dim_factored):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": [state_for(p) for p in jax.tree.leaves(params)],
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay_rate)
    eps = 1e-30

    def upd(g, s, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if "vr" in s:
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            vhat = (vr / denom)[..., None] * vc[..., None, :]
            new_s = {"vr": vr, "vc": vc}
        else:
            vhat = beta2 * s["v"] + (1 - beta2) * g2
            new_s = {"v": vhat}
        update = gf / jnp.sqrt(vhat + eps)
        # relative step clipping (RMS-1)
        rms = jnp.sqrt(jnp.mean(update * update))
        update = update / jnp.maximum(1.0, rms)
        p_new = p.astype(jnp.float32) - lr * update \
            - lr * cfg.weight_decay * p.astype(jnp.float32)
        return p_new.astype(p.dtype), new_s

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    out = [upd(g, s, p) for g, s, p in zip(flat_g, state["v"], flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = [o[1] for o in out]
    return new_p, {"v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def opt_init(cfg: OptimizerConfig, params):
    return adamw_init(cfg, params) if cfg.name == "adamw" \
        else adafactor_init(cfg, params)


def opt_update(cfg: OptimizerConfig, grads, state, params):
    return adamw_update(cfg, grads, state, params) if cfg.name == "adamw" \
        else adafactor_update(cfg, grads, state, params)
