"""starcoder2-7b [dense] — GQA, RoPE.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152 [arXiv:2402.19173; hf]
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=32,
    mlp_kind="plain",
    norm="layernorm",
    notes="GQA kv=4, RoPE, LayerNorm (StarCoder2 uses LN).",
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=4,
    mlp_kind="plain",
    norm="layernorm",
)
