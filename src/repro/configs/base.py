"""Model configuration system.

A model is a stack of *super-blocks*: a repeating pattern of blocks (attn /
mamba / mlstm / slstm ...), each optionally MoE.  All 10 assigned
architectures are expressible as (pattern, repeats) plus head/dim settings,
which keeps the compiled HLO small (``lax.scan`` over the repeats).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block inside the repeating super-block pattern."""

    kind: str = "attn"            # attn | mamba | mlstm | slstm
    attn: str = "full"            # full | swa (sliding window) | local
    window: int = 0               # sliding/local window size (tokens)
    moe: bool = False             # MoE FFN instead of dense FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | enc_dec | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # layer stack = pattern repeated `repeats` times (+ optional prologue)
    pattern: Tuple[BlockSpec, ...]
    repeats: int
    prologue: Tuple[BlockSpec, ...] = ()   # e.g. deepseek's dense first layer
    head_dim: Optional[int] = None         # default d_model // num_heads
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0                # deepseek shared experts
    moe_d_ff: Optional[int] = None         # expert hidden dim (default d_ff)
    moe_capacity_factor: float = 1.25      # expert buffer slack (tokens may drop)
    moe_groups: int = 0                    # GShard group-local dispatch (0=off)
    moe_decode_drop_free: bool = True      # decode C=T (exact) vs capacity-bounded
    # --- MLA (deepseek) ---
    mla_kv_lora_rank: int = 0              # 0 = MLA off
    mla_q_lora_rank: int = 0
    mla_qk_nope_dim: int = 128
    mla_qk_rope_dim: int = 64
    mla_v_dim: int = 128
    # --- SSM (mamba) ---
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # --- xLSTM ---
    xlstm_heads: int = 4
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    max_source_positions: int = 1500
    # --- norms / embeddings ---
    mlp_kind: str = "gated"                # gated (SwiGLU) | plain (GELU)
    norm: str = "rmsnorm"                  # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    max_position: int = 1 << 20
    # --- dtypes ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- notes for DESIGN.md / dry-run bookkeeping ---
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return len(self.prologue) + len(self.pattern) * self.repeats

    @property
    def blocks(self) -> Tuple[BlockSpec, ...]:
        return tuple(self.prologue) + tuple(self.pattern) * self.repeats

    @property
    def uses_attention(self) -> bool:
        return any(b.kind == "attn" for b in self.blocks)

    @property
    def pure_full_attention(self) -> bool:
        """True if every sequence-mixing block is full attention (no window,
        no SSM) — such archs skip the long_500k shape."""
        return all(b.kind == "attn" and b.attn == "full" for b in self.blocks)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, h = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for b in self.blocks:
            if b.kind == "attn":
                if self.mla_kv_lora_rank:
                    r_kv, r_q = self.mla_kv_lora_rank, self.mla_q_lora_rank
                    nope, rope, vd = (self.mla_qk_nope_dim, self.mla_qk_rope_dim,
                                      self.mla_v_dim)
                    nh = self.num_heads
                    total += d * (r_q or d)                       # q down
                    total += (r_q or d) * nh * (nope + rope)      # q up
                    total += d * (r_kv + rope)                    # kv down
                    total += r_kv * nh * (nope + vd)              # kv up
                    total += nh * vd * d                          # o
                else:
                    total += d * self.num_heads * h               # q
                    total += 2 * d * self.num_kv_heads * h        # k,v
                    total += self.num_heads * h * d               # o
            elif b.kind == "mamba":
                d_in = self.ssm_expand * d
                total += 2 * d * d_in + d_in * d                  # in/out proj
                total += d_in * (self.ssm_conv_width + 2 * self.ssm_state_dim + 2)
            elif b.kind in ("mlstm", "slstm"):
                d_in = 2 * d
                total += 4 * d * d_in + d_in * d
            # FFN
            ff = self.moe_d_ff or self.d_ff
            mats = 3 if self.mlp_kind == "gated" else 2
            if b.moe:
                total += self.moe_num_experts * mats * d * ff
                total += self.moe_num_shared * mats * d * ff
                total += d * self.moe_num_experts                 # router
            elif self.d_ff > 0:
                total += mats * d * self.d_ff
        if self.encoder_layers:
            # encoder blocks (full attn + dense ffn) + decoder cross-attn
            mats = 3 if self.mlp_kind == "gated" else 2
            enc = self.encoder_layers * (4 * d * d + mats * d * self.d_ff)
            cross = len(self.blocks) * 4 * d * d
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if not self.moe_num_experts:
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        mats = 3 if self.mlp_kind == "gated" else 2
        inactive_experts = self.moe_num_experts - self.moe_top_k
        per_moe_block = inactive_experts * mats * d * ff
        n_moe = sum(1 for b in self.blocks if b.moe)
        return self.param_count() - n_moe * per_moe_block
