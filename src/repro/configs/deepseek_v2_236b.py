"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400
[arXiv:2405.04434; hf].  First layer uses a dense FFN (DeepSeek convention);
remaining 59 are MoE.  d_ff=1536 is the routed-expert hidden dim; the dense
first-layer FFN uses the standard 12288 intermediate size.
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,                      # dense (first-layer) FFN hidden
    vocab_size=102400,
    prologue=(BlockSpec(kind="attn", attn="full", moe=False),),
    pattern=(BlockSpec(kind="attn", attn="full", moe=True),),
    repeats=59,                      # 1 dense + 59 MoE = 60 layers
    moe_num_experts=160,
    moe_top_k=6,
    moe_num_shared=2,
    moe_d_ff=1536,
    mla_kv_lora_rank=512,
    mla_q_lora_rank=1536,
    mla_qk_nope_dim=128,
    mla_qk_rope_dim=64,
    mla_v_dim=128,
    norm="rmsnorm",
    notes="MLA attention (kv_lora 512 + rope 64); 2 shared + 160 routed top-6.",
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    prologue=(BlockSpec(kind="attn", attn="full", moe=False),),
    pattern=(BlockSpec(kind="attn", attn="full", moe=True),),
    repeats=3,
    moe_num_experts=8,
    moe_top_k=2,
    moe_capacity_factor=4.0,
    moe_num_shared=1,
    moe_d_ff=64,
    mla_kv_lora_rank=32,
    mla_q_lora_rank=48,
    mla_qk_nope_dim=16,
    mla_qk_rope_dim=8,
    mla_v_dim=16,
    norm="rmsnorm",
)
