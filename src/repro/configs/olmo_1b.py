"""olmo-1b [dense] — non-parametric LayerNorm.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304 [arXiv:2402.00838; hf]
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=16,
    norm="nonparam_ln",
    tie_embeddings=True,
    notes="OLMo: non-parametric LayerNorm (no scale/bias), MHA (kv=16).",
)

SMOKE = ModelConfig(
    name="olmo-smoke",
    family="dense",
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=4,
    norm="nonparam_ln",
    tie_embeddings=True,
)
