"""smollm-360m [dense] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=32,
    norm="rmsnorm",
    tie_embeddings=True,
    notes="llama-family small model; used for the end-to-end training example.",
)

SMOKE = ModelConfig(
    name="smollm-smoke",
    family="dense",
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=4,
    norm="rmsnorm",
    tie_embeddings=True,
)
