"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf]
Super-block: 7 mamba + 1 attention (1:7 ratio); MoE every OTHER layer
(Jamba applies MoE at 1:2 frequency — 36 MoE layers; all-MoE would be ~724B,
the alternating layout lands at the assigned ~398B).
"""
from .base import BlockSpec, ModelConfig

_PATTERN = (
    BlockSpec(kind="mamba", moe=True),
    BlockSpec(kind="mamba", moe=False),
    BlockSpec(kind="mamba", moe=True),
    BlockSpec(kind="mamba", moe=False),
    BlockSpec(kind="mamba", moe=True),
    BlockSpec(kind="mamba", moe=False),
    BlockSpec(kind="mamba", moe=True),
    BlockSpec(kind="attn", moe=False),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PATTERN,
    repeats=9,                       # 9 x 8 = 72 layers
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    norm="rmsnorm",
    notes="Mamba+attn 1:7 interleave; MoE every block (16e top-2).",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pattern=tuple([BlockSpec(kind="mamba", moe=True)] * 3
                  + [BlockSpec(kind="attn", moe=True)]),
    repeats=2,                       # 8 layers
    moe_num_experts=4,
    moe_top_k=2,
    moe_capacity_factor=4.0,
    moe_d_ff=128,
    ssm_state_dim=8,
    ssm_conv_width=4,
    ssm_expand=2,
    norm="rmsnorm",
)
