"""whisper-tiny [audio] — enc-dec, conv frontend (stub).

4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356;
unverified].  The conv/mel frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings for the encoder.
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=4,                        # 4 decoder layers
    encoder_layers=4,                 # 4 encoder layers
    max_source_positions=1500,
    mlp_kind="plain",
    norm="layernorm",
    rope_theta=0.0,                   # whisper uses learned abs positions
    max_position=4096,
    notes="Encoder-decoder backbone; conv frontend stubbed to frame embeds.",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=2,
    encoder_layers=2,
    max_source_positions=64,
    mlp_kind="plain",
    norm="layernorm",
    rope_theta=0.0,
    max_position=256,
)
