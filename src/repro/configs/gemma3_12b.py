"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]
Super-block: 5 local (sliding window 1024) + 1 global.
"""
from .base import BlockSpec, ModelConfig

_PATTERN = tuple([BlockSpec(kind="attn", attn="local", window=1024)] * 5
                 + [BlockSpec(kind="attn", attn="full")])

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    pattern=_PATTERN,
    repeats=8,                       # 8 x 6 = 48 layers
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    notes="5:1 local:global; local window 1024; 128k-context target.",
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=tuple([BlockSpec(kind="attn", attn="local", window=16)] * 2
                  + [BlockSpec(kind="attn", attn="full")]),
    repeats=2,
    norm="rmsnorm",
    tie_embeddings=True,
)
