"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

One module per assigned architecture; each exports ``CONFIG`` (the exact
assigned full config) and ``SMOKE`` (a reduced same-family config for CPU
smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import BlockSpec, ModelConfig

ARCH_IDS = [
    "jamba_1_5_large_398b",
    "gemma3_12b",
    "starcoder2_7b",
    "smollm_360m",
    "olmo_1b",
    "whisper_tiny",
    "chameleon_34b",
    "deepseek_v2_236b",
    "mixtral_8x22b",
    "xlstm_350m",
]

# accept dash aliases like "jamba-1.5-large-398b"
def _canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_canon(arch)}", package=__name__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_canon(arch)}", package=__name__)
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
