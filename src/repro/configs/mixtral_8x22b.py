"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768 [arXiv:2401.04088; hf]
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    pattern=(BlockSpec(kind="attn", attn="swa", window=4096, moe=True),),
    repeats=56,
    moe_num_experts=8,
    moe_top_k=2,
    moe_d_ff=16384,
    norm="rmsnorm",
    notes="8 experts top-2 every layer; SWA window 4096.",
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    pattern=(BlockSpec(kind="attn", attn="swa", window=32, moe=True),),
    repeats=4,
    moe_num_experts=4,
    moe_top_k=2,
    moe_capacity_factor=4.0,
    moe_d_ff=128,
    norm="rmsnorm",
)
