"""xlstm-350m [ssm] — sLSTM + mLSTM blocks.

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517;
unverified].  d_ff=0: xLSTM blocks carry their own up/down projections, no
separate FFN.  Pattern: 5 mLSTM : 1 sLSTM (xLSTM[7:1]-style interleave,
rounded to the 24-layer budget).
"""
from .base import BlockSpec, ModelConfig

_PATTERN = tuple([BlockSpec(kind="mlstm")] * 5 + [BlockSpec(kind="slstm")])

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=_PATTERN,
    repeats=4,                       # 4 x 6 = 24 layers
    xlstm_heads=4,
    norm="rmsnorm",
    tie_embeddings=True,
    notes="Recurrent: constant-size per-request state instead of KV cache.",
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    pattern=tuple([BlockSpec(kind="mlstm")] * 2 + [BlockSpec(kind="slstm")]),
    repeats=2,
    xlstm_heads=2,
    norm="rmsnorm",
    tie_embeddings=True,
)
