"""chameleon-34b [vlm] — early-fusion, VQ image tokens (stub tokenizer).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 [arXiv:2405.09818;
unverified].  Early fusion means image patches are VQ-quantized into the same
token stream; the VQ tokenizer is a STUB — ``input_specs()`` provides fused
token ids directly.
"""
from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=48,
    norm="rmsnorm",
    notes="Early-fusion VLM backbone == dense LM over fused VQ token stream.",
)

SMOKE = ModelConfig(
    name="chameleon-smoke",
    family="vlm",
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    pattern=(BlockSpec(kind="attn", attn="full"),),
    repeats=4,
    norm="rmsnorm",
)
