"""Paper §3.2: graph abstraction of a cluster with a given model placement.

Each compute node c_i becomes two vertices (c_i^in, c_i^out) joined by an edge
whose capacity is the node's token throughput.  Valid network connections
become edges with capacity bandwidth / per-token bytes:

  (1) coordinator -> c_i          iff c_i holds the FIRST layer
  (2) c_i -> coordinator          iff c_i holds the LAST layer
  (3) c_i -> c_j                  iff c_j holds layers immediately needed
                                  after inference on c_i:
                                      s_j <= e_i < e_j   (partial inference)
                                  or  e_i == s_j         (strict pipelining)

Max flow source->sink == max serving throughput (tokens/s) of the placement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .cluster import ClusterSpec, ModelProfile, COORDINATOR
from .maxflow import FlowNetwork, preflow_push
from .placement import Placement

SOURCE = ("source",)
SINK = ("sink",)


def node_in(name: str) -> Tuple[str, str]:
    return (name, "in")


def node_out(name: str) -> Tuple[str, str]:
    return (name, "out")


def connection_valid(placement: Placement, src: str, dst: str,
                     partial_inference: bool = True) -> bool:
    """Validity of a compute-node -> compute-node connection (criterion 3)."""
    a = placement.assignment.get(src)
    b = placement.assignment.get(dst)
    if a is None or b is None or src == dst:
        return False
    if partial_inference:
        return b.start <= a.end < b.end
    return a.end == b.start


@dataclasses.dataclass
class ClusterGraph:
    """Flow network + bookkeeping to map flows back onto cluster entities."""

    net: FlowNetwork
    placement: Placement
    # directed edge in cluster terms -> capacity (tokens/s)
    link_capacity: Dict[Tuple[str, str], float]
    node_capacity: Dict[str, float]

    def max_flow(self) -> Tuple[float, Dict[Tuple[str, str], float]]:
        """Run preflow-push; return (tokens/s, flow on cluster links).

        Flow keys use cluster node names with COORDINATOR for both the
        source and sink side so the scheduler can read them directly.
        """
        value, flow = preflow_push(self.net, SOURCE, SINK)
        out: Dict[Tuple[str, str], float] = {}
        for (u, v), f in flow.items():
            if f <= 1e-9:
                continue
            if u == SOURCE and isinstance(v, tuple) and v[1] == "in":
                out[(COORDINATOR, v[0])] = f
            elif v == SINK and isinstance(u, tuple) and u[1] == "out":
                out[(u[0], COORDINATOR)] = f
            elif (isinstance(u, tuple) and u[1] == "out"
                  and isinstance(v, tuple) and v[1] == "in"):
                out[(u[0], v[0])] = f
        return value, out


def build_graph(cluster: ClusterSpec, model: ModelProfile,
                placement: Placement, partial_inference: bool = True
                ) -> ClusterGraph:
    net = FlowNetwork()
    link_capacity: Dict[Tuple[str, str], float] = {}
    node_capacity: Dict[str, float] = {}

    for name, rng in placement.assignment.items():
        cap = cluster.node_token_throughput(name, model, rng.num_layers)
        node_capacity[name] = cap
        net.add_edge(node_in(name), node_out(name), cap)

    for name, rng in placement.assignment.items():
        # criterion 1: coordinator -> node holding layer 0
        if rng.start == 0 and cluster.link(COORDINATOR, name) is not None:
            cap = cluster.link_token_capacity(COORDINATOR, name, model)
            link_capacity[(COORDINATOR, name)] = cap
            net.add_edge(SOURCE, node_in(name), cap)
        # criterion 2: node holding last layer -> coordinator
        if rng.end == model.num_layers and cluster.link(name, COORDINATOR) is not None:
            cap = cluster.link_token_capacity(name, COORDINATOR, model)
            link_capacity[(name, COORDINATOR)] = cap
            net.add_edge(node_out(name), SINK, cap)

    for src in placement.assignment:
        for dst in placement.assignment:
            if src == dst:
                continue
            if cluster.link(src, dst) is None:
                continue
            if connection_valid(placement, src, dst, partial_inference):
                cap = cluster.link_token_capacity(src, dst, model)
                link_capacity[(src, dst)] = cap
                net.add_edge(node_out(src), node_in(dst), cap)

    return ClusterGraph(net=net, placement=placement,
                        link_capacity=link_capacity,
                        node_capacity=node_capacity)


def placement_throughput(cluster: ClusterSpec, model: ModelProfile,
                         placement: Placement,
                         partial_inference: bool = True) -> float:
    """Max serving throughput (tokens/s) of a placement — the paper's
    evaluation function for any placement (heuristic or MILP)."""
    if placement.validate():
        return 0.0
    graph = build_graph(cluster, model, placement, partial_inference)
    value, _ = graph.max_flow()
    return value


def compute_upper_bound(cluster: ClusterSpec, model: ModelProfile) -> float:
    """§3.4 early-stop bound: sum of node compute averaged over all layers."""
    total = sum(cluster.nodes[n].flops for n in cluster.node_names())
    per_layer = total / (model.flops_per_token_layer * model.num_layers)
    return per_layer
