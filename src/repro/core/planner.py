"""End-to-end Helix planner: cluster → placement → max-flow → scheduler.

Also hosts the fault-tolerance entry points:
  * ``replan_after_failure`` — node loss → re-solve placement on the reduced
    cluster, warm-started (LNS) from the surviving assignment.
  * ``reweight_for_straggler`` — capacity degradation → recompute max flow on
    the degraded graph (placement unchanged; cheap) and swap IWRR weights.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

from .cluster import ClusterSpec, ModelProfile, COORDINATOR
from .graph import ClusterGraph, build_graph, placement_throughput
from .milp import MILPOptions, PlacementResult, solve_placement
from .placement import Placement
from .scheduler import HelixScheduler, KVEstimator


@dataclasses.dataclass
class Plan:
    cluster: ClusterSpec
    model: ModelProfile
    placement: Placement
    graph: ClusterGraph
    flows: Dict[Tuple[str, str], float]
    throughput: float
    milp: Optional[PlacementResult] = None

    def make_scheduler(self, partial_inference: bool = True,
                       with_kv_estimation: bool = True) -> HelixScheduler:
        kv = KVEstimator.from_placement(self.cluster, self.model,
                                        self.placement) \
            if with_kv_estimation else None
        return HelixScheduler(self.cluster, self.model, self.placement,
                              self.flows, partial_inference, kv)


def plan(cluster: ClusterSpec, model: ModelProfile,
         options: Optional[MILPOptions] = None,
         placement: Optional[Placement] = None) -> Plan:
    """Solve (or adopt) a placement and derive flows for scheduling."""
    options = options or MILPOptions()
    milp_result = None
    if placement is None:
        milp_result = solve_placement(cluster, model, options)
        placement = milp_result.placement
    graph = build_graph(cluster, model, placement, options.partial_inference)
    value, flows = graph.max_flow()
    return Plan(cluster=cluster, model=model, placement=placement,
                graph=graph, flows=flows, throughput=value, milp=milp_result)


def replan_after_failure(old: Plan, failed_node: str,
                         options: Optional[MILPOptions] = None) -> Plan:
    """Elastic replanning on node failure.

    The surviving placement seeds the LNS (nodes keep their layer ranges
    unless moving them improves flow), so replanning is fast and the swap is
    incremental.
    """
    options = options or MILPOptions()
    cluster = old.cluster.remove_node(failed_node)
    surviving = {n: r for n, r in old.placement.assignment.items()
                 if n != failed_node}
    seed = Placement(surviving, old.model.num_layers,
                     meta={"method": "surviving"})
    # If the surviving placement still covers the model it becomes the LNS
    # incumbent automatically (solve_placement evaluates heuristics + MILP);
    # otherwise the MILP repairs coverage from scratch.
    result = solve_placement(cluster, old.model, options)
    if not seed.validate():
        surviving_tput = placement_throughput(cluster, old.model, seed,
                                              options.partial_inference)
        if surviving_tput > result.actual_throughput:
            return plan(cluster, old.model, options, placement=seed)
    return plan(cluster, old.model, options, placement=result.placement)


def reweight_for_straggler(current: Plan, node: str, factor: float) -> Plan:
    """Straggler mitigation: degrade ``node``'s capacity by ``factor`` and
    re-run max flow only (placement unchanged — no weights move)."""
    cluster = current.cluster.degrade_node(node, factor)
    return plan(cluster, current.model, placement=current.placement)
