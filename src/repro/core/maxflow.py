"""Highest-label preflow-push max flow (paper §3.2 uses preflow-push [6]).

Pure-Python implementation with the gap heuristic.  Capacities are floats
(tokens/s).  Validated against ``networkx.maximum_flow`` in tests.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Hashable, List, Mapping, Tuple

Node = Hashable
EPS = 1e-9


class FlowNetwork:
    """Directed graph with float capacities; parallel edges are merged."""

    def __init__(self) -> None:
        self.capacity: Dict[Tuple[Node, Node], float] = defaultdict(float)
        self.adj: Dict[Node, List[Node]] = defaultdict(list)
        self.nodes: set = set()

    def add_edge(self, u: Node, v: Node, cap: float) -> None:
        if u == v or cap <= 0:
            return
        if (u, v) not in self.capacity and (v, u) not in self.capacity:
            self.adj[u].append(v)
            self.adj[v].append(u)
        elif (u, v) not in self.capacity:
            # reverse edge exists; arcs already in adjacency
            pass
        self.capacity[(u, v)] += cap
        self.capacity.setdefault((v, u), 0.0)
        self.nodes.add(u)
        self.nodes.add(v)

    def edges(self):
        return [(u, v, c) for (u, v), c in self.capacity.items() if c > 0]


def preflow_push(net: FlowNetwork, source: Node, sink: Node
                 ) -> Tuple[float, Dict[Tuple[Node, Node], float]]:
    """Highest-label preflow-push with gap heuristic.

    Returns (max_flow_value, flow dict keyed by directed edge).

    Robustness: capacities are floats, so we use a *scale-relative* epsilon
    (absolute 1e-9 lets ~1e-8 rounding dust on 1e8-scale capacities ping-pong
    between two nodes forever) and enforce the standard 2n height bound —
    any excess stranded above it is numerical dust with no residual path to
    either terminal and is dropped.
    """
    if source not in net.nodes or sink not in net.nodes:
        return 0.0, {}

    nodes = list(net.nodes)
    n = len(nodes)
    cap = dict(net.capacity)
    scale = max((c for c in cap.values() if c > 0), default=1.0)
    EPS = max(1e-10 * scale, 1e-12)
    MAX_HEIGHT = 2 * n + 1
    flow: Dict[Tuple[Node, Node], float] = defaultdict(float)
    height: Dict[Node, int] = {v: 0 for v in nodes}
    excess: Dict[Node, float] = {v: 0.0 for v in nodes}
    # arc pointers for the current-arc heuristic
    arc_ptr: Dict[Node, int] = {v: 0 for v in nodes}
    # count of nodes at each height (gap heuristic)
    height_count = defaultdict(int)
    height_count[0] = n

    def residual(u: Node, v: Node) -> float:
        return cap.get((u, v), 0.0) - flow[(u, v)]

    def push(u: Node, v: Node) -> None:
        delta = min(excess[u], residual(u, v))
        flow[(u, v)] += delta
        flow[(v, u)] -= delta
        excess[u] -= delta
        excess[v] += delta

    # saturate source arcs
    height[source] = n
    height_count[0] -= 1
    height_count[n] += 1
    for v in net.adj[source]:
        if residual(source, v) > EPS:
            excess[source] += residual(source, v)
            push(source, v)

    # bucket-based highest-label selection
    buckets: Dict[int, List[Node]] = defaultdict(list)
    in_bucket: Dict[Node, bool] = defaultdict(bool)

    def activate(v: Node) -> None:
        if v not in (source, sink) and excess[v] > EPS and not in_bucket[v]:
            buckets[height[v]].append(v)
            in_bucket[v] = True

    for v in nodes:
        activate(v)
    highest = max([h for h, b in buckets.items() if b], default=-1)

    while highest >= 0:
        if not buckets[highest]:
            highest -= 1
            continue
        u = buckets[highest].pop()
        in_bucket[u] = False
        if excess[u] <= EPS:
            continue
        # discharge u
        while excess[u] > EPS:
            neigh = net.adj[u]
            if arc_ptr[u] >= len(neigh):
                # relabel
                old_h = height[u]
                min_h = None
                for v in neigh:
                    if residual(u, v) > EPS:
                        if min_h is None or height[v] < min_h:
                            min_h = height[v]
                if min_h is None:
                    excess[u] = 0.0  # isolated: drop excess (shouldn't happen)
                    break
                if min_h + 1 > MAX_HEIGHT:
                    # No residual path to source or sink within the height
                    # bound: this excess is numerical dust — drop it.
                    excess[u] = 0.0
                    break
                height[u] = min_h + 1
                arc_ptr[u] = 0
                height_count[old_h] -= 1
                height_count[height[u]] += 1
                # gap heuristic: no nodes left at old_h → lift everything
                # above old_h (below n) straight to n+1.
                if height_count[old_h] == 0 and old_h < n:
                    for w in nodes:
                        if w not in (source,) and old_h < height[w] <= n and w != sink:
                            height_count[height[w]] -= 1
                            height[w] = n + 1
                            height_count[n + 1] += 1
            else:
                v = neigh[arc_ptr[u]]
                if residual(u, v) > EPS and height[u] == height[v] + 1:
                    push(u, v)
                    activate(v)
                else:
                    arc_ptr[u] += 1
        if excess[u] > EPS:
            activate(u)
        highest = max([h for h, b in buckets.items() if b], default=-1)

    value = sum(flow[(source, v)] for v in net.adj[source])
    # keep only positive flows on real edges
    out = {e: f for e, f in flow.items()
           if f > EPS and cap.get(e, 0.0) > 0}
    return value, out


def max_flow(edges: Mapping[Tuple[Node, Node], float], source: Node,
             sink: Node) -> Tuple[float, Dict[Tuple[Node, Node], float]]:
    """Convenience wrapper: edges dict -> (value, flow assignment)."""
    net = FlowNetwork()
    for (u, v), c in edges.items():
        net.add_edge(u, v, c)
    return preflow_push(net, source, sink)
