"""Model placement representation + the paper's baseline heuristics.

A placement maps each compute node to a contiguous layer interval
``[start, end)`` of the model.  Helix's MILP (milp.py) searches over these;
this module holds the shared datatype and the three heuristics the paper
compares against / warm-starts from:

* **Swarm** [31]: partition the model into equal-length stages; assign nodes
  to stages balancing per-stage compute capacity.
* **Petals** [4]: nodes choose greedily, covering the layers with the least
  accumulated compute, holding as many layers as VRAM allows.
* **Separate pipelines (SP)**: one homogeneous pipeline per device type,
  layers split evenly within each pipeline.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Mapping, Optional, Tuple

from .cluster import ClusterSpec, ModelProfile, COORDINATOR


@dataclasses.dataclass(frozen=True)
class LayerRange:
    start: int
    end: int  # exclusive

    @property
    def num_layers(self) -> int:
        return max(0, self.end - self.start)

    def overlaps(self, other: "LayerRange") -> bool:
        return self.start < other.end and other.start < self.end


@dataclasses.dataclass
class Placement:
    """node name -> layer range.  Nodes holding zero layers are omitted."""

    assignment: Dict[str, LayerRange]
    num_layers: int
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def validate(self) -> List[str]:
        """Return a list of problems (empty == valid)."""
        problems = []
        covered = [0] * self.num_layers
        for node, rng in self.assignment.items():
            if rng.num_layers <= 0:
                problems.append(f"{node}: empty range {rng}")
            if rng.start < 0 or rng.end > self.num_layers:
                problems.append(f"{node}: out of bounds {rng}")
            for l in range(max(rng.start, 0), min(rng.end, self.num_layers)):
                covered[l] += 1
        missing = [l for l, c in enumerate(covered) if c == 0]
        if missing:
            problems.append(f"uncovered layers: {missing[:8]}{'...' if len(missing) > 8 else ''}")
        return problems

    def holders_of(self, layer: int) -> List[str]:
        return sorted(n for n, r in self.assignment.items()
                      if r.start <= layer < r.end)

    def roles(self) -> Dict[str, str]:
        """Replica role per node (``prefill`` / ``decode`` / ``mixed``).
        Placements without explicit roles treat every node as mixed."""
        roles = (self.meta or {}).get("roles") or {}
        return {n: roles.get(n, "mixed") for n in self.assignment}

    def layer_compute(self, cluster: ClusterSpec, model: ModelProfile) -> List[float]:
        """Tokens/s of capacity covering each layer (the min over layers is
        the classic pipeline-bottleneck metric from §3.1)."""
        out = [0.0] * self.num_layers
        for node, rng in self.assignment.items():
            tput = cluster.node_token_throughput(node, model, rng.num_layers)
            for l in range(rng.start, rng.end):
                out[l] += tput
        return out


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode (HexGen-2-style replica roles)
# ---------------------------------------------------------------------------

def disaggregated_placement(prefill: Mapping[str, LayerRange],
                            decode: Mapping[str, LayerRange],
                            num_layers: int) -> Placement:
    """Build a placement split into prefill and decode replica groups.

    Each group must cover ``[0, num_layers)`` on its own: prompt passes run
    only on the prefill group, decode passes only on the decode group, and
    the filled KV is handed from the former to the latter over a peer link.
    A node listed in both groups (same range) becomes ``mixed`` — its KV is
    already home, so no handoff is shipped for its layers.
    """
    assignment: Dict[str, LayerRange] = {}
    roles: Dict[str, str] = {}
    for group, role in ((prefill, "prefill"), (decode, "decode")):
        for node, rng in group.items():
            if node in assignment and assignment[node] != rng:
                raise ValueError(
                    f"{node} appears in both groups with conflicting "
                    f"ranges {assignment[node]} vs {rng}")
            assignment[node] = rng
            roles[node] = "mixed" if node in roles else role
    for name, group in (("prefill", prefill), ("decode", decode)):
        sub = Placement(dict(group), num_layers)
        bad = sub.validate()
        if bad:
            raise ValueError(f"{name} group does not cover the model: {bad}")
    return Placement(assignment, num_layers,
                     meta={"method": "disaggregated", "roles": roles})


# ---------------------------------------------------------------------------
# Heuristic baselines
# ---------------------------------------------------------------------------

def swarm_placement(cluster: ClusterSpec, model: ModelProfile,
                    num_stages: Optional[int] = None,
                    param_frac: float = 0.5) -> Placement:
    """Equal-length stages; nodes assigned to stages to balance compute.

    The paper sets #stages to the minimum that lets the weakest GPU hold one
    stage with half its VRAM.
    """
    names = cluster.node_names()
    if num_stages is None:
        weakest_layers = min(
            max(1, cluster.max_layers_on(n, model, param_frac)) for n in names)
        num_stages = max(1, math.ceil(model.num_layers / weakest_layers))
    num_stages = min(num_stages, model.num_layers, len(names))
    # split layers into (nearly) equal stages
    bounds = [round(i * model.num_layers / num_stages) for i in range(num_stages + 1)]
    stages = [LayerRange(bounds[i], bounds[i + 1]) for i in range(num_stages)]
    # sort nodes by capacity desc, assign each to the stage with least compute
    stage_compute = [0.0] * num_stages
    assignment: Dict[str, LayerRange] = {}
    for node in sorted(names, key=lambda n: -cluster.nodes[n].flops):
        i = min(range(num_stages), key=lambda s: stage_compute[s])
        assignment[node] = stages[i]
        stage_compute[i] += cluster.node_token_throughput(
            node, model, stages[i].num_layers)
    return Placement(assignment, model.num_layers, meta={"method": "swarm",
                                                         "num_stages": num_stages})


def petals_placement(cluster: ClusterSpec, model: ModelProfile,
                     param_frac: float = 0.5) -> Placement:
    """Greedy: each node (in arbitrary join order) picks the contiguous window
    it can hold that currently has the least total compute coverage."""
    names = cluster.node_names()
    coverage = [0.0] * model.num_layers
    assignment: Dict[str, LayerRange] = {}
    for node in names:
        k = cluster.max_layers_on(node, model, param_frac)
        k = max(1, min(k, model.num_layers))
        best_start, best_cov = 0, float("inf")
        window = sum(coverage[:k])
        best_cov, best_start = window, 0
        for s in range(1, model.num_layers - k + 1):
            window += coverage[s + k - 1] - coverage[s - 1]
            if window < best_cov - 1e-12:
                best_cov, best_start = window, s
        rng = LayerRange(best_start, best_start + k)
        assignment[node] = rng
        tput = cluster.node_token_throughput(node, model, k)
        for l in range(rng.start, rng.end):
            coverage[l] += tput
    return Placement(assignment, model.num_layers, meta={"method": "petals"})


def separate_pipelines_placement(cluster: ClusterSpec, model: ModelProfile,
                                 param_frac: float = 0.5,
                                 allow_mixed_tail: bool = False) -> Placement:
    """One pipeline per device type; even layer split inside each pipeline.

    Device types whose members cannot jointly hold the model form no pipeline
    (paper: SP excludes them; SP+ builds one mixed pipeline from leftovers —
    enabled via ``allow_mixed_tail``)."""
    by_type: Dict[str, List[str]] = defaultdict(list)
    for name in cluster.node_names():
        key = f"{cluster.nodes[name].device.name}x{cluster.nodes[name].tp_degree}"
        by_type[key].append(name)

    assignment: Dict[str, LayerRange] = {}
    leftovers: List[str] = []
    for dev, members in sorted(by_type.items()):
        per_node_max = cluster.max_layers_on(members[0], model, param_frac)
        if per_node_max <= 0:
            leftovers.extend(members)
            continue
        need = math.ceil(model.num_layers / per_node_max)
        if len(members) < need:
            leftovers.extend(members)
            continue
        # greedily form ⌊len/need⌋ replicas; spare nodes join leftovers
        num_replicas = len(members) // need
        used = num_replicas * need
        leftovers.extend(members[used:])
        for r in range(num_replicas):
            group = members[r * need:(r + 1) * need]
            bounds = [round(i * model.num_layers / need) for i in range(need + 1)]
            for i, node in enumerate(group):
                assignment[node] = LayerRange(bounds[i], bounds[i + 1])

    if allow_mixed_tail and leftovers:
        mixed = _mixed_pipeline(cluster, model, leftovers, param_frac)
        assignment.update(mixed)
    return Placement(assignment, model.num_layers,
                     meta={"method": "separate_pipelines",
                           "unused_nodes": [] if allow_mixed_tail else leftovers})


def _mixed_pipeline(cluster: ClusterSpec, model: ModelProfile,
                    members: List[str], param_frac: float) -> Dict[str, LayerRange]:
    """Chain leftover nodes into one pipeline, each holding its VRAM max,
    proportionally shrunk to exactly cover the model if oversubscribed."""
    caps = {n: max(1, cluster.max_layers_on(n, model, param_frac)) for n in members}
    total = sum(caps.values())
    if total < model.num_layers:
        return {}
    assignment: Dict[str, LayerRange] = {}
    cursor = 0
    remaining = model.num_layers
    ordered = sorted(members, key=lambda n: -caps[n])
    for i, n in enumerate(ordered):
        left_nodes = len(ordered) - i
        rest_cap = sum(caps[m] for m in ordered[i + 1:])
        # balanced share, but never leave more than the rest can cover
        take = min(caps[n], remaining)
        take = max(take if left_nodes == 1 else min(take, math.ceil(remaining / left_nodes)),
                   remaining - rest_cap)
        if take > 0:
            assignment[n] = LayerRange(cursor, cursor + take)
            cursor += take
            remaining -= take
    if remaining > 0:
        return {}
    return assignment
