"""Helix core: max-flow/MILP placement + per-request pipeline scheduling."""
from .cluster import (COORDINATOR, DEVICE_PROFILES, LLAMA_30B, LLAMA_70B,
                      ClusterSpec, DeviceProfile, LinkSpec, ModelProfile,
                      NodeSpec, full_mesh_cluster, make_distributed_cluster,
                      make_high_heterogeneity_cluster, make_serving_cluster,
                      make_single_cluster, make_tpu_pod_cluster)
from .graph import (ClusterGraph, build_graph, compute_upper_bound,
                    connection_valid, placement_throughput)
from .maxflow import FlowNetwork, max_flow, preflow_push
from .milp import MILPOptions, PlacementResult, solve_placement
from .mix_planner import (SLO, Bucket, MixPlan, ThroughputTable,
                          TrafficProfile, best_homogeneous, mix_is_feasible,
                          solve_mix)
from .placement import (LayerRange, Placement, disaggregated_placement,
                        petals_placement, separate_pipelines_placement,
                        swarm_placement)
from .planner import Plan, plan, replan_after_failure, reweight_for_straggler
from .scheduler import (IWRR, BaseScheduler, HelixScheduler, KVEstimator,
                        PipelineStage, RandomScheduler, RequestPipeline,
                        SwarmScheduler)
