"""Paper §3.3–3.4: MILP model placement via max-flow maximization.

Variables (Table 2):
  s_i      int     first layer node i holds
  b_i^j    binary  node i holds exactly j layers (j = 1..k_i)
  f_{u,v}  real    flow on candidate connection (u,v)
  d_{u,v}  binary  connection validity
  cond1/2  binary  aux for the partial-inference validity linearization

Constraints (Table 3): placement validity, flow conservation, inference
throughput, connection validity, transmission throughput.  Objective:
maximize sum of flow out of the source.

Solver: scipy.optimize.milp (HiGHS).  The paper uses Gurobi; HiGHS has no
warm-start API, so §3.4's "hint with heuristic solutions" is reproduced as
(a) an incumbent lower bound from the best heuristic and (b) LNS
(fix-and-reoptimize) around the incumbent.  §3.4's other speedups — cluster
pruning and the compute-sum upper bound — are implemented directly.

Note on the paper's no-partial-inference linearization: the text gives
``L*d <= L + s_j - e_i`` and ``L*d >= L - s_j + e_i``; the latter direction
is inconsistent (both reduce to e_i <= s_j).  We use the pair
``L*d <= L + s_j - e_i`` and ``L*d <= L - s_j + e_i``, whose conjunction
correctly forces e_i == s_j when d == 1.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, milp

from .cluster import ClusterSpec, ModelProfile, COORDINATOR
from .graph import build_graph, compute_upper_bound, placement_throughput
from .placement import (LayerRange, Placement, petals_placement,
                        separate_pipelines_placement, swarm_placement)

SRC = "__source__"
SNK = "__sink__"


@dataclasses.dataclass
class MILPOptions:
    partial_inference: bool = True
    prune_degree: Optional[int] = 12
    time_limit_s: float = 60.0
    mip_rel_gap: float = 0.01
    warm_start: bool = True
    lns_rounds: int = 4
    lns_neighborhood: int = 6
    lns_time_limit_s: float = 15.0
    # Beyond-paper: flow-guided local search refinement of the best solution
    # (see local_search.py) — fast anytime improvement with the exact
    # preflow-push evaluator; also strengthens the LNS incumbent.
    fgls_rounds: int = 40
    use_upper_bound: bool = True
    # Beyond-paper MILP strengthening: clamp every capacity at the §3.4
    # compute-sum bound (no single edge can carry more than the total flow,
    # which the bound caps) — big-M coefficients drop from ~3e8 to ~1e4 and
    # the LP relaxation tightens dramatically.
    clamp_capacity_at_bound: bool = True
    # Beyond-paper: identical nodes (same device/region/tp) are
    # interchangeable; order their start layers to break symmetry.
    symmetry_breaking: bool = True
    param_frac: float = 0.5  # VRAM fraction for params (rest = KV cache)
    seed: int = 0
    verbose: bool = False


@dataclasses.dataclass
class PlacementResult:
    placement: Placement
    predicted_throughput: float   # MILP objective value
    actual_throughput: float      # preflow-push on the resulting graph
    status: str
    solve_time_s: float
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Candidate connection set (§3.4 cluster pruning)
# ---------------------------------------------------------------------------

def candidate_edges(cluster: ClusterSpec, prune_degree: Optional[int]
                    ) -> List[Tuple[str, str]]:
    """Compute-compute candidate links, optionally pruned to a target degree.

    Pruning keeps the highest-bandwidth (then lowest-latency) out-links per
    node; coordinator links are never pruned.
    """
    names = cluster.node_names()
    edges: List[Tuple[str, str]] = []
    for src in names:
        # Tie-break equal-bandwidth links by a deterministic hash so pruning
        # spreads the kept links across the mesh (sorting by name makes every
        # node keep the same 12 peers, destroying connectivity).
        import hashlib

        def _spread(dst: str) -> int:
            return int(hashlib.md5(f"{src}->{dst}".encode()).hexdigest()[:8], 16)

        outs = [(l.bandwidth_bytes_per_s, -l.latency_s, _spread(l.dst), l.dst)
                for l in cluster.out_links(src)
                if l.dst != COORDINATOR and l.dst in cluster.nodes]
        outs.sort(reverse=True)
        if prune_degree is not None:
            outs = outs[:prune_degree]
        edges.extend((src, dst) for _, _, _, dst in outs)
    return edges


# ---------------------------------------------------------------------------
# MILP construction
# ---------------------------------------------------------------------------

class _VarRegistry:
    def __init__(self) -> None:
        self.names: List[str] = []
        self.lb: List[float] = []
        self.ub: List[float] = []
        self.integrality: List[int] = []
        self.index: Dict[str, int] = {}

    def add(self, name: str, lb: float, ub: float, integer: bool) -> int:
        idx = len(self.names)
        self.names.append(name)
        self.lb.append(lb)
        self.ub.append(ub)
        self.integrality.append(1 if integer else 0)
        self.index[name] = idx
        return idx

    def __getitem__(self, name: str) -> int:
        return self.index[name]

    def __len__(self) -> int:
        return len(self.names)


class _ConstraintBuilder:
    def __init__(self, nvars: int) -> None:
        self.rows: List[Dict[int, float]] = []
        self.lo: List[float] = []
        self.hi: List[float] = []
        self.nvars = nvars

    def add(self, coeffs: Mapping[int, float], lo: float, hi: float) -> None:
        self.rows.append(dict(coeffs))
        self.lo.append(lo)
        self.hi.append(hi)

    def build(self) -> LinearConstraint:
        data, ri, ci = [], [], []
        for r, row in enumerate(self.rows):
            for c, v in row.items():
                ri.append(r)
                ci.append(c)
                data.append(v)
        mat = sparse.csr_matrix((data, (ri, ci)),
                                shape=(len(self.rows), self.nvars))
        return LinearConstraint(mat, np.array(self.lo), np.array(self.hi))


@dataclasses.dataclass
class _Problem:
    reg: _VarRegistry
    cons: _ConstraintBuilder
    objective: np.ndarray
    nodes: List[str]
    k_of: Dict[str, int]
    edges: List[Tuple[str, str]]
    L: int


def _build_problem(cluster: ClusterSpec, model: ModelProfile,
                   options: MILPOptions,
                   fixed: Optional[Mapping[str, LayerRange]] = None
                   ) -> _Problem:
    L = model.num_layers
    names = cluster.node_names()
    # Nodes that cannot hold even one layer are excluded from placement.
    k_of = {n: min(L, cluster.max_layers_on(n, model, options.param_frac))
            for n in names}
    nodes = [n for n in names if k_of[n] >= 1]
    edges = [(u, v) for (u, v) in candidate_edges(cluster, options.prune_degree)
             if u in set(nodes) and v in set(nodes)]

    # Clamp capacities at the total-flow bound: no edge can carry more than
    # the sum of all compute, so this is exact — and it shrinks big-Ms.
    flow_cap = compute_upper_bound(cluster, model) \
        if options.clamp_capacity_at_bound else float("inf")

    reg = _VarRegistry()
    fixed = fixed or {}
    for n in nodes:
        if n in fixed:
            rng = fixed[n]
            reg.add(f"s[{n}]", rng.start, rng.start, True)
            for j in range(1, k_of[n] + 1):
                val = 1.0 if j == rng.num_layers else 0.0
                reg.add(f"b[{n},{j}]", val, val, True)
        else:
            reg.add(f"s[{n}]", 0, L - 1, True)
            for j in range(1, k_of[n] + 1):
                reg.add(f"b[{n},{j}]", 0, 1, True)

    for n in nodes:
        cap = cluster.link_token_capacity(COORDINATOR, n, model) \
            if cluster.link(COORDINATOR, n) else 0.0
        cap = min(cap, flow_cap)
        reg.add(f"f[{SRC},{n}]", 0, cap, False)
        reg.add(f"d[{SRC},{n}]", 0, 1 if cap > 0 else 0, True)
        cap = cluster.link_token_capacity(n, COORDINATOR, model) \
            if cluster.link(n, COORDINATOR) else 0.0
        cap = min(cap, flow_cap)
        reg.add(f"f[{n},{SNK}]", 0, cap, False)
        reg.add(f"d[{n},{SNK}]", 0, 1 if cap > 0 else 0, True)

    # For edges whose BOTH endpoints are fixed, connection validity is a
    # constant — pre-resolve it so LNS sub-problems shed most binaries.
    def _fixed_validity(u: str, v: str) -> Optional[bool]:
        if u not in fixed or v not in fixed:
            return None
        a, b = fixed[u], fixed[v]
        if options.partial_inference:
            return b.start <= a.end < b.end
        return a.end == b.start

    for (u, v) in edges:
        cap = min(cluster.link_token_capacity(u, v, model), flow_cap)
        known = _fixed_validity(u, v)
        reg.add(f"f[{u},{v}]", 0, cap if known in (None, True) else 0.0, False)
        if known is None:
            reg.add(f"d[{u},{v}]", 0, 1, True)
        else:
            reg.add(f"d[{u},{v}]", int(known), int(known), True)
        if options.partial_inference and known is None:
            reg.add(f"c1[{u},{v}]", 0, 1, True)
            reg.add(f"c2[{u},{v}]", 0, 1, True)

    cons = _ConstraintBuilder(len(reg))

    def e_terms(n: str, sign: float) -> Dict[int, float]:
        """Coefficients of e_n = s_n + sum_j j*b_n^j, scaled by sign."""
        out = {reg[f"s[{n}]"]: sign}
        for j in range(1, k_of[n] + 1):
            out[reg[f"b[{n},{j}]"]] = sign * j
        return out

    def _merge(*ds: Mapping[int, float]) -> Dict[int, float]:
        out: Dict[int, float] = {}
        for d in ds:
            for k, val in d.items():
                out[k] = out.get(k, 0.0) + val
        return out

    in_edges: Dict[str, List[str]] = {n: [] for n in nodes}
    out_edges: Dict[str, List[str]] = {n: [] for n in nodes}
    for (u, v) in edges:
        out_edges[u].append(f"f[{u},{v}]")
        in_edges[v].append(f"f[{u},{v}]")
    for n in nodes:
        in_edges[n].append(f"f[{SRC},{n}]")
        out_edges[n].append(f"f[{n},{SNK}]")

    for n in nodes:
        # C1: exactly one b; e_i <= L
        cons.add({reg[f"b[{n},{j}]"]: 1.0 for j in range(1, k_of[n] + 1)}, 1, 1)
        cons.add(e_terms(n, +1.0), -np.inf, L)
        # C2: flow conservation
        row = {reg[f]: 1.0 for f in in_edges[n]}
        for f in out_edges[n]:
            row[reg[f]] = row.get(reg[f], 0.0) - 1.0
        cons.add(row, 0, 0)
        # C3: inference throughput, sum_in f <= sum_j T_n^j b_n^j
        row = {reg[f]: 1.0 for f in in_edges[n]}
        for j in range(1, k_of[n] + 1):
            t = cluster.node_token_throughput(n, model, j)
            row[reg[f"b[{n},{j}]"]] = row.get(reg[f"b[{n},{j}]"], 0.0) - t
        cons.add(row, -np.inf, 0)
        # C4 source: s_i + L*d_src <= L
        cons.add({reg[f"s[{n}]"]: 1.0, reg[f"d[{SRC},{n}]"]: float(L)},
                 -np.inf, L)
        # C4 sink: L*d_sink - e_i <= 0
        cons.add(_merge({reg[f"d[{n},{SNK}]"]: float(L)}, e_terms(n, -1.0)),
                 -np.inf, 0)
        # C5 source/sink transmission: f <= cap * d
        cap = reg.ub[reg[f"f[{SRC},{n}]"]]
        cons.add({reg[f"f[{SRC},{n}]"]: 1.0, reg[f"d[{SRC},{n}]"]: -cap},
                 -np.inf, 0)
        cap = reg.ub[reg[f"f[{n},{SNK}]"]]
        cons.add({reg[f"f[{n},{SNK}]"]: 1.0, reg[f"d[{n},{SNK}]"]: -cap},
                 -np.inf, 0)

    for (u, v) in edges:
        if _fixed_validity(u, v) is not None:
            # d already pinned; only the f <= cap*d row below is needed.
            pass
        elif options.partial_inference:
            # cond1 = 1 only if s_v <= e_u:  s_v - e_u + (L+1)c1 <= L+1
            cons.add(_merge({reg[f"s[{v}]"]: 1.0,
                             reg[f"c1[{u},{v}]"]: float(L + 1)},
                            e_terms(u, -1.0)),
                     -np.inf, L + 1)
            # cond2 = 1 only if e_u < e_v:   e_u - e_v + (L+1)c2 <= L
            cons.add(_merge(e_terms(u, +1.0), e_terms(v, -1.0),
                            {reg[f"c2[{u},{v}]"]: float(L + 1)}),
                     -np.inf, L)
            # d <= 0.5c1 + 0.5c2
            cons.add({reg[f"d[{u},{v}]"]: 1.0,
                      reg[f"c1[{u},{v}]"]: -0.5,
                      reg[f"c2[{u},{v}]"]: -0.5}, -np.inf, 0)
        else:
            # d = 1 only if e_u == s_v (see module docstring for the fix):
            # L*d - s_v + e_u <= L   and   L*d + s_v - e_u <= L
            cons.add(_merge({reg[f"d[{u},{v}]"]: float(L),
                             reg[f"s[{v}]"]: -1.0}, e_terms(u, +1.0)),
                     -np.inf, L)
            cons.add(_merge({reg[f"d[{u},{v}]"]: float(L),
                             reg[f"s[{v}]"]: 1.0}, e_terms(u, -1.0)),
                     -np.inf, L)
        # C5: f <= cap * d
        cap = reg.ub[reg[f"f[{u},{v}]"]]
        cons.add({reg[f"f[{u},{v}]"]: 1.0,
                  reg[f"d[{u},{v}]"]: -cap}, -np.inf, 0)

    # §3.4 compute-sum upper bound on total source flow
    if options.use_upper_bound:
        ub = compute_upper_bound(cluster, model)
        cons.add({reg[f"f[{SRC},{n}]"]: 1.0 for n in nodes}, -np.inf, ub)

    # Symmetry breaking: identical free nodes get ordered start layers.
    if options.symmetry_breaking and not fixed:
        groups: Dict[Tuple, List[str]] = {}
        for n in nodes:
            spec = cluster.nodes[n]
            key = (spec.device.name, spec.region, spec.tp_degree)
            groups.setdefault(key, []).append(n)
        for members in groups.values():
            members.sort()
            for a, b in zip(members, members[1:]):
                # s_a <= s_b
                cons.add({reg[f"s[{a}]"]: 1.0, reg[f"s[{b}]"]: -1.0},
                         -np.inf, 0)

    obj = np.zeros(len(reg))
    for n in nodes:
        obj[reg[f"f[{SRC},{n}]"]] = -1.0  # milp minimizes

    return _Problem(reg=reg, cons=cons, objective=obj, nodes=nodes,
                    k_of=k_of, edges=edges, L=L)


def _solve(problem: _Problem, options: MILPOptions,
           time_limit: Optional[float] = None) -> Tuple[Optional[Placement], float, str]:
    reg = problem.reg
    res = milp(
        c=problem.objective,
        constraints=problem.cons.build(),
        integrality=np.array(reg.integrality),
        bounds=Bounds(np.array(reg.lb), np.array(reg.ub)),
        options={
            "time_limit": time_limit or options.time_limit_s,
            "mip_rel_gap": options.mip_rel_gap,
            "disp": options.verbose,
        },
    )
    if res.x is None:
        return None, 0.0, f"status={res.status} ({res.message})"
    assignment: Dict[str, LayerRange] = {}
    for n in problem.nodes:
        s = int(round(res.x[reg[f"s[{n}]"]]))
        num = 0
        best = 0.0
        for j in range(1, problem.k_of[n] + 1):
            val = res.x[reg[f"b[{n},{j}]"]]
            if val > best:
                best, num = val, j
        assignment[n] = LayerRange(s, s + num)
    placement = Placement(assignment, problem.L, meta={"method": "milp"})
    return placement, -float(res.fun), f"status={res.status}"


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def heuristic_incumbents(cluster: ClusterSpec, model: ModelProfile,
                         options: MILPOptions) -> List[Tuple[str, Placement, float]]:
    out = []
    for name, fn in [("swarm", swarm_placement),
                     ("petals", petals_placement),
                     ("separate_pipelines", separate_pipelines_placement)]:
        try:
            p = fn(cluster, model, param_frac=options.param_frac)
        except TypeError:
            p = fn(cluster, model)
        if p.validate():
            continue
        t = placement_throughput(cluster, model, p, options.partial_inference)
        out.append((name, p, t))
    out.sort(key=lambda x: -x[2])
    return out


def solve_placement(cluster: ClusterSpec, model: ModelProfile,
                    options: Optional[MILPOptions] = None) -> PlacementResult:
    """End-to-end Helix placement: heuristics → MILP → LNS refinement."""
    options = options or MILPOptions()
    rng = random.Random(options.seed)
    t0 = time.time()

    incumbents = heuristic_incumbents(cluster, model, options)
    best_placement: Optional[Placement] = incumbents[0][1] if incumbents else None
    best_value = incumbents[0][2] if incumbents else 0.0
    history = [{"phase": "heuristic:" + n, "throughput": t}
               for n, _, t in incumbents]

    problem = _build_problem(cluster, model, options)
    placement, predicted, status = _solve(problem, options)
    milp_actual = 0.0
    if placement is not None and not placement.validate():
        milp_actual = placement_throughput(cluster, model, placement,
                                           options.partial_inference)
        history.append({"phase": "milp", "throughput": milp_actual,
                        "predicted": predicted, "status": status})
        if milp_actual > best_value:
            best_placement, best_value = placement, milp_actual

    # Beyond-paper: flow-guided local search on the incumbent.
    if options.fgls_rounds and best_placement is not None:
        from .local_search import FGLSOptions, refine_placement
        refined, val, _hist = refine_placement(
            cluster, model, best_placement,
            FGLSOptions(rounds=options.fgls_rounds,
                        partial_inference=options.partial_inference,
                        param_frac=options.param_frac, seed=options.seed))
        history.append({"phase": "fgls", "throughput": val})
        if val > best_value + 1e-9:
            best_placement, best_value = refined, val

    # §3.4 warm start, reproduced as LNS fix-and-reoptimize around incumbent.
    if options.warm_start and best_placement is not None and options.lns_rounds:
        nodes = [n for n in problem.nodes]
        for r in range(options.lns_rounds):
            if len(nodes) <= options.lns_neighborhood:
                break
            # alternate: bottleneck-guided neighborhoods and random ones
            if r % 2 == 0 and best_placement is not None:
                per_layer = best_placement.layer_compute(cluster, model)
                worst = min(range(len(per_layer)), key=lambda l: per_layer[l])
                near = [n for n in nodes
                        if n in best_placement.assignment
                        and abs((best_placement.assignment[n].start
                                 + best_placement.assignment[n].end) / 2
                                - worst) <= model.num_layers / 3]
                rng.shuffle(near)
                free = set(near[:options.lns_neighborhood])
                pool = [n for n in nodes if n not in free]
                while len(free) < options.lns_neighborhood and pool:
                    free.add(pool.pop(rng.randrange(len(pool))))
            else:
                free = set(rng.sample(nodes, options.lns_neighborhood))
            fixed = {n: best_placement.assignment[n] for n in nodes
                     if n not in free and n in best_placement.assignment}
            sub = _build_problem(cluster, model, options, fixed=fixed)
            cand, pred, st = _solve(sub, options,
                                    time_limit=options.lns_time_limit_s)
            if cand is None or cand.validate():
                continue
            val = placement_throughput(cluster, model, cand,
                                       options.partial_inference)
            history.append({"phase": f"lns[{r}]", "throughput": val,
                            "predicted": pred, "status": st})
            if val > best_value + 1e-9:
                best_placement, best_value = cand, val

    if best_placement is None:
        raise RuntimeError("no feasible placement found (cluster too small "
                           "to hold the model?)")
    return PlacementResult(
        placement=best_placement,
        predicted_throughput=predicted if placement is not None else 0.0,
        actual_throughput=best_value,
        status=status,
        solve_time_s=time.time() - t0,
        meta={"history": history,
              "num_vars": len(problem.reg),
              "num_constraints": len(problem.cons.rows),
              "upper_bound": compute_upper_bound(cluster, model)},
    )
