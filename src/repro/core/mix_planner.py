"""Cost/SLO-aware GPU-mix planning (Mélange-style).

Helix's planner answers "place the model on THIS cluster"; this module
answers the question before it: "which cluster should I rent?".  Following
Mélange ("Cost Efficiency of Multi-GPU Serving"), traffic is bucketed by
(input-len, output-len), each device type gets a *bucketed throughput
table* — requests/s one node sustains per bucket, zeroed where the type
cannot meet the TTFT/TPOT SLO — and a solver picks the cheapest node mix
whose aggregate table capacity covers the measured demand.  The result is
an ordinary ``ClusterSpec`` that feeds the existing MILP ``plan()``, so
"choose the cluster" composes with "place the model on it".

Throughput model (the same §3.2 arithmetic the placement graph uses):
a node's model-normalized token rate is

    T(dev) = min(flops / (flops_per_token_layer * num_layers),
                 max_tokens_per_s, nic_bytes_per_s / activation_bytes)

i.e. the tokens/s it contributes to a pipeline when layers are split
proportional to compute (the max-flow upper bound ``compute_upper_bound``
is exactly the sum of these).  A bucket (i, o) costs i + o tokens per
request, so one node serves ``T / (i + o)`` requests/s of that bucket.
SLO gating is per (device, bucket): solo decode TPOT ``1 / T`` must meet
``slo.tpot_s`` and prefilling ``i`` tokens at ``prefill_speedup * T`` must
meet ``slo.ttft_s``.  ``tests/test_mix_planner.py`` checks the table
against the event simulator so the arithmetic cannot silently drift from
what the runtime/simulator actually deliver.

Solvers: a greedy + flow-checked-trim baseline with no dependencies
(feasibility of a candidate mix is an exact bipartite max-flow over the
repo's own ``preflow_push``), and an optional CP-SAT formulation (ortools,
per the Mélange/edge-placement idiom) used when available — never Gurobi.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .cluster import (COORDINATOR, DEVICE_PROFILES, ClusterSpec,
                      DeviceProfile, LinkSpec, ModelProfile, NodeSpec,
                      _full_mesh_links)
from .maxflow import FlowNetwork, preflow_push


# ---------------------------------------------------------------------------
# traffic: (input-len, output-len) buckets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bucket:
    """One (input-len, output-len) traffic bucket (bucket centers)."""

    input_len: int
    output_len: int

    @property
    def tokens(self) -> int:
        return self.input_len + self.output_len

    def __str__(self) -> str:
        return f"{self.input_len}in/{self.output_len}out"


@dataclasses.dataclass
class TrafficProfile:
    """Measured (or target) traffic: total request rate + bucket weights."""

    rate_rps: float
    buckets: List[Bucket]
    weights: List[float]

    def __post_init__(self) -> None:
        if self.rate_rps < 0:
            raise ValueError(f"rate_rps must be >= 0, got {self.rate_rps}")
        if len(self.buckets) != len(self.weights) or not self.buckets:
            raise ValueError("buckets and weights must be non-empty and "
                             "the same length")
        tot = float(sum(self.weights))
        if tot <= 0:
            raise ValueError("weights must sum > 0")
        self.weights = [w / tot for w in self.weights]

    def demand_rps(self) -> List[float]:
        """Requests/s per bucket."""
        return [self.rate_rps * w for w in self.weights]

    def demand_tokens(self) -> List[float]:
        """Tokens/s per bucket (requests/s x tokens per request)."""
        return [self.rate_rps * w * b.tokens
                for w, b in zip(self.weights, self.buckets)]

    def tokens_per_s(self) -> float:
        return sum(self.demand_tokens())

    @staticmethod
    def from_requests(pairs: Sequence[Tuple[int, int]], rate_rps: float,
                      edges: Sequence[int] = (128, 512, 2048)
                      ) -> "TrafficProfile":
        """Histogram observed (input_len, output_len) pairs into buckets.

        ``edges`` are upper input-length bounds; output lengths share the
        same edges.  Bucket centers are the mean of the member requests,
        so the profile reflects what was actually seen, not bin midpoints.
        This is what the autoscaler feeds the mix solver from live stats.
        """
        if not pairs:
            raise ValueError("no requests to profile")

        def edge_of(n: int) -> int:
            for k, e in enumerate(edges):
                if n <= e:
                    return k
            return len(edges)

        groups: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for i, o in pairs:
            groups.setdefault((edge_of(i), edge_of(o)), []).append((i, o))
        buckets, weights = [], []
        for key in sorted(groups):
            mem = groups[key]
            buckets.append(Bucket(
                input_len=max(1, round(sum(i for i, _ in mem) / len(mem))),
                output_len=max(1, round(sum(o for _, o in mem) / len(mem)))))
            weights.append(float(len(mem)))
        return TrafficProfile(rate_rps=rate_rps, buckets=buckets,
                              weights=weights)


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets gating the throughput table."""

    ttft_s: Optional[float] = None   # time to first token (prefill)
    tpot_s: Optional[float] = None   # time per output token (decode)


# ---------------------------------------------------------------------------
# bucketed per-device-type throughput table
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ThroughputTable:
    """Per-device-type bucketed throughput: ``rates[dev][b]`` is the
    requests/s ONE node of that type sustains for bucket ``b`` (0 when the
    type cannot meet the SLO for that bucket, or cannot hold even one layer
    of the model); ``token_rate[dev]`` is its model-normalized tokens/s."""

    model: ModelProfile
    buckets: List[Bucket]
    devices: Dict[str, DeviceProfile]
    token_rate: Dict[str, float]
    rates: Dict[str, List[float]]
    max_layers: Dict[str, int]
    prefill_speedup: float
    slo: SLO

    @staticmethod
    def profile(model: ModelProfile, buckets: Sequence[Bucket],
                device_names: Sequence[str] = ("A100", "V100", "L4", "T4"),
                *, slo: SLO = SLO(), param_frac: float = 0.5,
                prefill_speedup: float = 2.0,
                devices: Optional[Mapping[str, DeviceProfile]] = None
                ) -> "ThroughputTable":
        """One-time bucketed profiling pass (the Mélange tput tables).

        ``prefill_speedup`` models prefill's better FLOP utilization vs the
        (already-derated) decode rate — prefill is one big batched matmul,
        decode is memory-bound single rows.
        """
        devs = {n: (devices or DEVICE_PROFILES)[n] for n in device_names}
        token_rate: Dict[str, float] = {}
        rates: Dict[str, List[float]] = {}
        max_layers: Dict[str, int] = {}
        for name, d in devs.items():
            t = min(d.flops / (model.flops_per_token_layer * model.num_layers),
                    d.max_tokens_per_s,
                    d.nic_bytes_per_s / model.activation_bytes)
            token_rate[name] = t
            max_layers[name] = int((d.vram_bytes * param_frac)
                                   // model.layer_param_bytes)
            row: List[float] = []
            for b in buckets:
                ok = max_layers[name] >= 1 and t > 0
                if ok and slo.tpot_s is not None:
                    ok = (1.0 / t) <= slo.tpot_s
                if ok and slo.ttft_s is not None:
                    ok = b.input_len / (t * prefill_speedup) <= slo.ttft_s
                row.append(t / b.tokens if ok else 0.0)
            rates[name] = row
        return ThroughputTable(model=model, buckets=list(buckets),
                               devices=devs, token_rate=token_rate,
                               rates=rates, max_layers=max_layers,
                               prefill_speedup=prefill_speedup, slo=slo)

    def feasible_pairs(self) -> List[Tuple[str, int]]:
        return [(g, bi) for g, row in self.rates.items()
                for bi, r in enumerate(row) if r > 0]


# ---------------------------------------------------------------------------
# mix feasibility: exact bipartite max-flow (bucket demand -> type capacity)
# ---------------------------------------------------------------------------

def _served_fraction(table: ThroughputTable, traffic: TrafficProfile,
                     counts: Mapping[str, int]) -> float:
    """Fraction of the bucketed token demand a mix can serve, via max flow:
    source -> bucket (demand tokens/s) -> device type (edge iff the type is
    SLO-feasible for the bucket) -> sink (count x token rate).  1.0 means
    the mix covers the traffic exactly (fractional assignment, which IWRR
    scheduling delivers)."""
    demand = traffic.demand_tokens()
    total = sum(demand)
    if total <= 0:
        return 1.0
    net = FlowNetwork()
    src, snk = ("mix", "src"), ("mix", "snk")
    for bi, d in enumerate(demand):
        if d > 0:
            net.add_edge(src, ("b", bi), d)
    for g, bi in table.feasible_pairs():
        if demand[bi] > 0 and counts.get(g, 0) > 0:
            # big-M, not inf: preflow_push scales its epsilon off the max
            # capacity, so an inf edge would wash out every push
            net.add_edge(("b", bi), ("g", g), total)
    for g, n in counts.items():
        if n > 0:
            net.add_edge(("g", g), snk, n * table.token_rate[g])
    value, _ = preflow_push(net, src, snk)
    return value / total


def mix_is_feasible(table: ThroughputTable, traffic: TrafficProfile,
                    counts: Mapping[str, int]) -> bool:
    covered = (sum(table.max_layers[g] * n for g, n in counts.items())
               >= table.model.num_layers)
    return covered and _served_fraction(table, traffic, counts) >= 1 - 1e-9


# ---------------------------------------------------------------------------
# solvers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MixPlan:
    """A solved GPU mix: counts per device type + what it promises."""

    counts: Dict[str, int]
    cost_per_hour: float
    predicted_rate_rps: float        # max servable rate of THIS mix
    table: ThroughputTable
    traffic: TrafficProfile
    solver: str

    @property
    def num_nodes(self) -> int:
        return sum(self.counts.values())

    def cluster(self, *, bandwidth_bytes_per_s: float = 10e9 / 8,
                latency_s: float = 1e-3) -> ClusterSpec:
        """Materialize the mix as a single-region full-mesh ``ClusterSpec``
        — the object the existing MILP ``plan()`` consumes."""
        nodes: Dict[str, NodeSpec] = {}
        regions: Dict[str, str] = {COORDINATOR: "r0"}
        for g in sorted(self.counts):
            for i in range(self.counts[g]):
                name = f"{g.lower()}-{i}"
                nodes[name] = NodeSpec(name, self.table.devices[g],
                                       region="r0")
                regions[name] = "r0"
        links = _full_mesh_links(list(nodes), regions,
                                 bandwidth_bytes_per_s, latency_s,
                                 bandwidth_bytes_per_s, latency_s)
        return ClusterSpec(nodes=nodes, links=links)

    def describe(self) -> str:
        mix = "+".join(f"{n}x{g}" for g, n in sorted(self.counts.items())
                       if n > 0)
        return (f"mix[{mix} ${self.cost_per_hour:.2f}/hr "
                f"rate<={self.predicted_rate_rps:.2f}rps via {self.solver}]")


def _mix_cost(table: ThroughputTable, counts: Mapping[str, int]) -> float:
    return sum(table.devices[g].cost_per_hour * n
               for g, n in counts.items())


def _predicted_rate(table: ThroughputTable, traffic: TrafficProfile,
                    counts: Mapping[str, int]) -> float:
    """Max request rate (same bucket shape) the mix can serve: binary-search
    the rate multiplier where the served fraction stays 1."""
    if traffic.rate_rps <= 0:
        return 0.0
    lo, hi = 0.0, 1.0
    # grow hi until infeasible (or absurdly large)
    for _ in range(40):
        t = dataclasses.replace(traffic, rate_rps=traffic.rate_rps * hi,
                                weights=list(traffic.weights))
        if _served_fraction(table, t, counts) < 1 - 1e-9:
            break
        lo = hi
        hi *= 2
    else:
        return traffic.rate_rps * lo
    for _ in range(30):
        mid = (lo + hi) / 2
        t = dataclasses.replace(traffic, rate_rps=traffic.rate_rps * mid,
                                weights=list(traffic.weights))
        if _served_fraction(table, t, counts) >= 1 - 1e-9:
            lo = mid
        else:
            hi = mid
    return traffic.rate_rps * lo


def _solve_greedy(table: ThroughputTable, traffic: TrafficProfile,
                  max_per_type: int) -> Dict[str, int]:
    """Cheapest-per-absorbed-token greedy + exact-flow trim.

    Repeatedly add one node of the type with the best $/(tokens/s of
    *residual* demand it can absorb); buckets with fewer feasible types are
    absorbed first so a cheap generalist does not starve a bucket only an
    expensive specialist can serve.  A trim pass then drops any node the
    exact feasibility flow proves redundant (fixes greedy's rounding)."""
    demand = traffic.demand_tokens()
    residual = list(demand)
    counts: Dict[str, int] = {g: 0 for g in table.rates}
    feas: Dict[str, List[int]] = {
        g: [bi for bi, r in enumerate(row) if r > 0]
        for g, row in table.rates.items()}
    # options per bucket, to absorb scarce buckets first
    n_opts = [sum(1 for g in feas if bi in feas[g])
              for bi in range(len(demand))]
    for bi, d in enumerate(demand):
        if d > 0 and n_opts[bi] == 0:
            raise ValueError(
                f"bucket {table.buckets[bi]} has demand but no device type "
                f"meets its SLO — relax the SLO or add device types")

    while any(r > 1e-9 for r in residual):
        best, best_eff, best_gain = None, float("inf"), 0.0
        for g in table.rates:
            if counts[g] >= max_per_type:
                continue
            gain = min(table.token_rate[g],
                       sum(residual[bi] for bi in feas[g]))
            if gain <= 1e-12:
                continue
            cost = table.devices[g].cost_per_hour
            eff = cost / gain if cost > 0 else 0.0
            if eff < best_eff - 1e-15 or (abs(eff - best_eff) <= 1e-15
                                          and gain > best_gain):
                best, best_eff, best_gain = g, eff, gain
        if best is None:
            raise ValueError(
                "greedy mix solve ran out of capacity before covering "
                f"demand (max_per_type={max_per_type})")
        counts[best] += 1
        cap = table.token_rate[best]
        for bi in sorted(feas[best], key=lambda b: n_opts[b]):
            take = min(cap, residual[bi])
            residual[bi] -= take
            cap -= take
            if cap <= 1e-12:
                break
    # model coverage: enough total VRAM to hold every layer somewhere
    def covered() -> bool:
        return (sum(table.max_layers[g] * n for g, n in counts.items())
                >= table.model.num_layers)
    while not covered():
        cands = [g for g in table.rates
                 if table.max_layers[g] > 0 and counts[g] < max_per_type]
        if not cands:
            raise ValueError("cannot cover the model's layers within "
                             f"max_per_type={max_per_type}")
        g = min(cands, key=lambda g: table.devices[g].cost_per_hour
                / table.max_layers[g])
        counts[g] += 1
    # trim: drop nodes the exact flow check proves redundant, priciest first
    for g in sorted(counts, key=lambda g: -table.devices[g].cost_per_hour):
        while counts[g] > 0:
            counts[g] -= 1
            if not mix_is_feasible(table, traffic, counts):
                counts[g] += 1
                break
    return counts


def _solve_cpsat(table: ThroughputTable, traffic: TrafficProfile,
                 max_per_type: int, time_limit_s: float
                 ) -> Optional[Dict[str, int]]:
    """CP-SAT mix formulation (optional; ortools only, never Gurobi):
    integer node counts n_g, integer-scaled bucket-load assignment x_gb,
    sum_g x_gb >= demand_b, sum_b x_gb <= n_g * rate_g, minimize cost.
    Returns None when ortools is unavailable or the solve fails."""
    try:
        from ortools.sat.python import cp_model
    except ImportError:
        return None
    SCALE = 1000                      # token/s -> integer milli-tokens/s
    demand = traffic.demand_tokens()
    model = cp_model.CpModel()
    n = {g: model.NewIntVar(0, max_per_type, f"n_{g}")
         for g in table.rates}
    x: Dict[Tuple[str, int], object] = {}
    horizon = int(sum(demand) * SCALE) + 1
    for g, bi in table.feasible_pairs():
        if demand[bi] > 0:
            x[(g, bi)] = model.NewIntVar(0, horizon, f"x_{g}_{bi}")
    for bi, d in enumerate(demand):
        if d <= 0:
            continue
        terms = [x[(g, bi)] for g in table.rates if (g, bi) in x]
        if not terms:
            raise ValueError(
                f"bucket {table.buckets[bi]} has demand but no device type "
                f"meets its SLO — relax the SLO or add device types")
        model.Add(sum(terms) >= math.ceil(d * SCALE))
    for g in table.rates:
        terms = [x[(g, bi)] for bi in range(len(demand)) if (g, bi) in x]
        if terms:
            model.Add(sum(terms) <= n[g] * int(table.token_rate[g] * SCALE))
    # model coverage: total max layers across the mix >= num_layers
    model.Add(sum(n[g] * table.max_layers[g] for g in table.rates)
              >= table.model.num_layers)
    model.Minimize(sum(
        n[g] * int(round(table.devices[g].cost_per_hour * 100))
        for g in table.rates))
    solver = cp_model.CpSolver()
    solver.parameters.max_time_in_seconds = time_limit_s
    status = solver.Solve(model)
    if status not in (cp_model.OPTIMAL, cp_model.FEASIBLE):
        return None
    return {g: int(solver.Value(n[g])) for g in table.rates}


def solve_mix(model: ModelProfile, traffic: TrafficProfile,
              device_names: Sequence[str] = ("A100", "V100", "L4", "T4"),
              *, slo: SLO = SLO(), solver: str = "auto",
              max_per_type: int = 64, headroom: float = 1.0,
              param_frac: float = 0.5, prefill_speedup: float = 2.0,
              cpsat_time_limit_s: float = 10.0,
              table: Optional[ThroughputTable] = None) -> MixPlan:
    """Solve for the cheapest GPU mix serving ``traffic`` under ``slo``.

    ``headroom`` > 1 over-provisions (the autoscaler plans for 1.2-1.5x the
    measured rate so a drift does not immediately re-trigger).  ``solver``:
    "greedy" (always available), "cpsat" (requires ortools; raises if
    missing), or "auto" (CP-SAT when importable, greedy otherwise — and
    greedy as fallback when CP-SAT proves nothing within its time limit).
    """
    if headroom <= 0:
        raise ValueError(f"headroom must be > 0, got {headroom}")
    if table is None:
        table = ThroughputTable.profile(model, traffic.buckets,
                                        device_names, slo=slo,
                                        param_frac=param_frac,
                                        prefill_speedup=prefill_speedup)
    want = dataclasses.replace(traffic,
                               rate_rps=traffic.rate_rps * headroom,
                               weights=list(traffic.weights))
    if solver not in ("auto", "greedy", "cpsat"):
        raise ValueError(f"unknown solver {solver!r}")
    counts: Optional[Dict[str, int]] = None
    used = solver
    if solver in ("auto", "cpsat"):
        counts = _solve_cpsat(table, want, max_per_type, cpsat_time_limit_s)
        used = "cpsat"
        if counts is None and solver == "cpsat":
            raise RuntimeError("solver='cpsat' requires ortools "
                               "(pip install ortools) — use 'greedy'/'auto'")
        if counts is not None and not mix_is_feasible(table, want, counts):
            counts = None            # scaled-integer rounding fell short
    if counts is None:
        counts = _solve_greedy(table, want, max_per_type)
        used = "greedy"
    counts = {g: n for g, n in counts.items() if n > 0}
    return MixPlan(counts=counts,
                   cost_per_hour=_mix_cost(table, counts),
                   predicted_rate_rps=_predicted_rate(table, traffic,
                                                      counts),
                   table=table, traffic=traffic, solver=used)


def best_homogeneous(model: ModelProfile, traffic: TrafficProfile,
                     device_names: Sequence[str] = ("A100", "V100", "L4",
                                                    "T4"),
                     *, slo: SLO = SLO(), max_per_type: int = 64,
                     headroom: float = 1.0, param_frac: float = 0.5,
                     prefill_speedup: float = 2.0,
                     table: Optional[ThroughputTable] = None
                     ) -> Optional[MixPlan]:
    """Cheapest SINGLE-type cluster meeting the traffic (the baseline the
    mix must beat); None when no one type can serve every bucket."""
    if table is None:
        table = ThroughputTable.profile(model, traffic.buckets,
                                        device_names, slo=slo,
                                        param_frac=param_frac,
                                        prefill_speedup=prefill_speedup)
    want = dataclasses.replace(traffic,
                               rate_rps=traffic.rate_rps * headroom,
                               weights=list(traffic.weights))
    best: Optional[MixPlan] = None
    for g in table.rates:
        if any(d > 0 and table.rates[g][bi] <= 0
               for bi, d in enumerate(want.demand_tokens())):
            continue                  # this type cannot serve some bucket
        if table.max_layers[g] < 1:
            continue
        need = math.ceil(want.tokens_per_s()
                         / max(table.token_rate[g], 1e-12) - 1e-9)
        need = max(need, math.ceil(table.model.num_layers
                                   / table.max_layers[g]))
        need = max(need, 1)
        counts = {g: need}
        while need <= max_per_type and \
                not mix_is_feasible(table, want, counts):
            need += 1
            counts = {g: need}
        if need > max_per_type:
            continue
        cost = _mix_cost(table, counts)
        if best is None or cost < best.cost_per_hour:
            best = MixPlan(counts=counts, cost_per_hour=cost,
                           predicted_rate_rps=_predicted_rate(
                               table, traffic, counts),
                           table=table, traffic=traffic,
                           solver="homogeneous")
    return best
