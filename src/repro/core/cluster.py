"""Cluster specification for Helix planning.

A cluster is a coordinator plus a set of heterogeneous compute nodes joined by
network links.  This module is hardware-agnostic: a "node" can be a single
GPU (the paper's setting) or a TPU slice (our adaptation); all the planner
sees is a throughput profile (tokens/s as a function of #layers held), a VRAM
budget, and link bandwidth/latency.

Capacities follow the paper's §3.2 graph abstraction:
  * node capacity  = min(compute tokens/s, NIC tokens/s)
  * link capacity  = bandwidth / per-token transmission size
    (tokens coordinator<->node are ~4 B; activations node<->node are
     ~2*d_model bytes in fp16).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

COORDINATOR = "coordinator"


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Profiled performance of one device type.

    ``token_throughput(num_layers)`` follows the paper's one-time profiling:
    the max number of tokens/s a node can process when holding ``num_layers``
    layers.  We model it as ``flops_per_s / flops_per_token_per_layer /
    num_layers`` saturated by a per-node batching ceiling.
    """

    name: str
    # Effective sustained FLOP/s for transformer inference (already derated
    # from peak; the paper profiles tokens/s directly).
    flops: float
    vram_bytes: float
    # NIC bandwidth in bytes/s (node-level network processing ceiling).
    nic_bytes_per_s: float
    # Max tokens the engine can batch per second regardless of layer count
    # (scheduler / engine overhead ceiling).
    max_tokens_per_s: float = 5.0e5
    # Rental price in $/hr (on-demand cloud list-ish) — the objective the
    # Mélange-style mix planner minimizes.  0.0 means "not priced" (free),
    # which keeps cost-unaware callers unchanged.
    cost_per_hour: float = 0.0

    def tokens_per_s(self, num_layers: int, flops_per_token_layer: float) -> float:
        if num_layers <= 0:
            return 0.0
        t = self.flops / (flops_per_token_layer * num_layers)
        return min(t, self.max_tokens_per_s)


# --- Device profiles -------------------------------------------------------
# GPU profiles mirror the paper's cluster (A100 / V100 / L4 / T4); numbers are
# effective serving FLOP/s (~40% of peak fp16 dense) and full VRAM.  TPU
# profiles are the v5e targets used for the TPU-adapted clusters.
DEVICE_PROFILES: Dict[str, DeviceProfile] = {
    "A100": DeviceProfile("A100", flops=312e12 * 0.40, vram_bytes=80e9, nic_bytes_per_s=1.25e9, cost_per_hour=3.67),
    "V100": DeviceProfile("V100", flops=125e12 * 0.40, vram_bytes=32e9, nic_bytes_per_s=1.25e9, cost_per_hour=2.48),
    "L4": DeviceProfile("L4", flops=121e12 * 0.40, vram_bytes=24e9, nic_bytes_per_s=1.25e9, cost_per_hour=0.81),
    "T4": DeviceProfile("T4", flops=65e12 * 0.40, vram_bytes=16e9, nic_bytes_per_s=1.25e9, cost_per_hour=0.35),
    # TPU v5e chip: 197 TFLOP/s bf16 peak, 16 GB HBM.
    "TPUv5e": DeviceProfile("TPUv5e", flops=197e12 * 0.45, vram_bytes=16e9, nic_bytes_per_s=6.25e9, cost_per_hour=1.20),
    # A 4-chip v5e slice acting as one Helix node (TP within the slice).
    "TPUv5e-4": DeviceProfile("TPUv5e-4", flops=4 * 197e12 * 0.42, vram_bytes=64e9, nic_bytes_per_s=6.25e9, cost_per_hour=4.80),
    "TPUv5e-8": DeviceProfile("TPUv5e-8", flops=8 * 197e12 * 0.40, vram_bytes=128e9, nic_bytes_per_s=6.25e9, cost_per_hour=9.60),
}


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One compute node (GPU or TPU slice) in the cluster."""

    name: str
    device: DeviceProfile
    region: str = "r0"
    # Tensor-parallel degree inside the node (multi-GPU node / TPU slice).
    tp_degree: int = 1
    # Per-node $/hr override; None prices the node from its device profile
    # (tp_degree GPUs rented together).
    hourly_cost: Optional[float] = None

    @property
    def flops(self) -> float:
        return self.device.flops * self.tp_degree

    @property
    def vram_bytes(self) -> float:
        return self.device.vram_bytes * self.tp_degree

    @property
    def cost_per_hour(self) -> float:
        if self.hourly_cost is not None:
            return self.hourly_cost
        return self.device.cost_per_hour * self.tp_degree


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Directed network link between two nodes (or coordinator<->node)."""

    src: str
    dst: str
    bandwidth_bytes_per_s: float
    latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Serving-relevant facts about the model being placed."""

    name: str
    num_layers: int
    d_model: int
    # Bytes of parameters for one layer (fp16/bf16).
    layer_param_bytes: float
    # FLOPs to process one token through one layer (decode-phase, amortized).
    flops_per_token_layer: float
    # Bytes of KV cache per token per layer.
    kv_bytes_per_token_layer: float
    # Activation size per token at a layer boundary (what pipelines transmit).
    activation_bytes: float
    # Token id transmission size coordinator<->node.
    token_bytes: float = 4.0

    @staticmethod
    def from_dims(name: str, num_layers: int, d_model: int, d_ff: int,
                  vocab: int, n_kv_heads: int, head_dim: int,
                  dtype_bytes: float = 2.0, moe_experts: int = 0,
                  moe_topk: int = 0, kv_dtype: str = "param",
                  kv_page_size: int = 16) -> "ModelProfile":
        # Per-layer params: attn (qkvo) + mlp.  MoE multiplies the FFN by the
        # expert count for *storage* but only top-k for *compute*.
        attn = 4 * d_model * d_model
        ffn = 3 * d_model * d_ff  # gated mlp
        storage_ffn = ffn * (moe_experts if moe_experts else 1)
        compute_ffn = ffn * (moe_topk if moe_topk else 1)
        layer_param_bytes = (attn + storage_ffn) * dtype_bytes
        flops_per_token_layer = 2 * (attn + compute_ffn)
        if kv_dtype == "int8":
            # int8 pages: 1 byte/element + one f32 absmax per (page, kv_head)
            # for K and V each, amortized over the page's tokens — mirrors
            # serving.kv_pool.page_bytes so the planner/simulator see the
            # same ~2x capacity the engines actually get
            kv = (2 * n_kv_heads * head_dim * 1.0
                  + 2 * n_kv_heads * 4.0 / kv_page_size)
        elif kv_dtype in (None, "param"):
            kv = 2 * n_kv_heads * head_dim * dtype_bytes
        else:
            raise ValueError(f"kv_dtype must be 'param' or 'int8', "
                             f"got {kv_dtype!r}")
        return ModelProfile(
            name=name,
            num_layers=num_layers,
            d_model=d_model,
            layer_param_bytes=layer_param_bytes,
            flops_per_token_layer=flops_per_token_layer,
            kv_bytes_per_token_layer=kv,
            activation_bytes=d_model * dtype_bytes,
        )


# Models used in the paper's evaluation.
LLAMA_30B = ModelProfile.from_dims("llama-30b", num_layers=60, d_model=6656,
                                   d_ff=17920, vocab=32000, n_kv_heads=52,
                                   head_dim=128)
LLAMA_70B = ModelProfile.from_dims("llama-70b", num_layers=80, d_model=8192,
                                   d_ff=28672, vocab=32000, n_kv_heads=8,
                                   head_dim=128)


@dataclasses.dataclass
class ClusterSpec:
    """Coordinator + nodes + directed links."""

    nodes: Dict[str, NodeSpec]
    links: Dict[Tuple[str, str], LinkSpec]
    coordinator_region: str = "r0"

    # ------------------------------------------------------------------
    def node_names(self) -> List[str]:
        return sorted(self.nodes)

    def out_links(self, name: str) -> List[LinkSpec]:
        return [l for (s, _), l in sorted(self.links.items()) if s == name]

    def in_links(self, name: str) -> List[LinkSpec]:
        return [l for (_, d), l in sorted(self.links.items()) if d == name]

    def link(self, src: str, dst: str) -> Optional[LinkSpec]:
        return self.links.get((src, dst))

    def remove_node(self, name: str) -> "ClusterSpec":
        """Fault tolerance: cluster with ``name`` removed (links pruned)."""
        nodes = {k: v for k, v in self.nodes.items() if k != name}
        links = {k: v for k, v in self.links.items()
                 if name not in (k[0], k[1])}
        return ClusterSpec(nodes=nodes, links=links,
                           coordinator_region=self.coordinator_region)

    def cost_per_hour(self) -> float:
        """Total rental price of the cluster in $/hr (coordinator is free)."""
        return sum(n.cost_per_hour for n in self.nodes.values())

    def add_node(self, spec: NodeSpec, *,
                 bandwidth_bytes_per_s: Optional[float] = None,
                 latency_s: Optional[float] = None) -> "ClusterSpec":
        """Elastic scale-up: cluster with ``spec`` added, full-mesh linked to
        the coordinator and every existing node.  Link bandwidth/latency
        default to the median of the existing links so a grown cluster keeps
        the fabric it already has."""
        if spec.name in self.nodes or spec.name == COORDINATOR:
            raise ValueError(f"node {spec.name!r} already exists")
        if self.links and (bandwidth_bytes_per_s is None or latency_s is None):
            bws = sorted(l.bandwidth_bytes_per_s for l in self.links.values())
            lats = sorted(l.latency_s for l in self.links.values())
            if bandwidth_bytes_per_s is None:
                bandwidth_bytes_per_s = bws[len(bws) // 2]
            if latency_s is None:
                latency_s = lats[len(lats) // 2]
        bw = bandwidth_bytes_per_s if bandwidth_bytes_per_s is not None \
            else 10e9 / 8
        lat = latency_s if latency_s is not None else 1e-3
        nodes = dict(self.nodes)
        nodes[spec.name] = spec
        links = dict(self.links)
        for other in [COORDINATOR] + list(self.nodes):
            links[(other, spec.name)] = LinkSpec(other, spec.name, bw, lat)
            links[(spec.name, other)] = LinkSpec(spec.name, other, bw, lat)
        return ClusterSpec(nodes=nodes, links=links,
                           coordinator_region=self.coordinator_region)

    def degrade_node(self, name: str, factor: float) -> "ClusterSpec":
        """Straggler modelling: scale a node's throughput by ``factor``."""
        node = self.nodes[name]
        dev = dataclasses.replace(node.device,
                                  flops=node.device.flops * factor,
                                  max_tokens_per_s=node.device.max_tokens_per_s * factor)
        nodes = dict(self.nodes)
        nodes[name] = dataclasses.replace(node, device=dev)
        return ClusterSpec(nodes=nodes, links=self.links,
                           coordinator_region=self.coordinator_region)

    # ------------------------------------------------------------------
    def max_layers_on(self, node: str, model: ModelProfile,
                      param_frac: float = 0.5) -> int:
        """Max layers a node can hold using ``param_frac`` of VRAM for params
        (the rest is reserved for KV-cache, mirroring Table 1's convention)."""
        budget = self.nodes[node].vram_bytes * param_frac
        return max(0, min(model.num_layers, int(budget // model.layer_param_bytes)))

    def node_token_throughput(self, node: str, model: ModelProfile,
                              num_layers: int) -> float:
        """Paper §3.2: node capacity = min(compute, NIC) in tokens/s."""
        if num_layers <= 0:
            return 0.0
        spec = self.nodes[node]
        compute = (spec.flops / (model.flops_per_token_layer * num_layers))
        compute = min(compute, spec.device.max_tokens_per_s)
        nic = spec.device.nic_bytes_per_s / model.activation_bytes
        return min(compute, nic)

    def link_token_capacity(self, src: str, dst: str, model: ModelProfile) -> float:
        link = self.links[(src, dst)]
        if COORDINATOR in (src, dst):
            per_token = model.token_bytes
        else:
            per_token = model.activation_bytes
        return link.bandwidth_bytes_per_s / per_token


# ---------------------------------------------------------------------------
# Cluster builders for the paper's three setups + TPU variants.
# ---------------------------------------------------------------------------

def _full_mesh_links(names: Sequence[str], regions: Mapping[str, str],
                     intra_bw: float, intra_lat: float,
                     inter_bw: float, inter_lat: float) -> Dict[Tuple[str, str], LinkSpec]:
    links: Dict[Tuple[str, str], LinkSpec] = {}
    all_names = [COORDINATOR] + list(names)
    for src in all_names:
        for dst in all_names:
            if src == dst:
                continue
            same = regions.get(src, "r0") == regions.get(dst, "r0")
            bw, lat = (intra_bw, intra_lat) if same else (inter_bw, inter_lat)
            links[(src, dst)] = LinkSpec(src, dst, bw, lat)
    return links


def full_mesh_cluster(devs, *, bandwidth: float = 10e9 / 8,
                      latency_s: float = 1e-3) -> ClusterSpec:
    """Single-region full-mesh cluster over named device types — or an int
    for that many A100s.  The builder the tests, their harness, and the
    benchmarks share for controlled-topology experiments."""
    if isinstance(devs, int):
        devs = ["A100"] * devs
    nodes: Dict[str, NodeSpec] = {}
    regions = {COORDINATOR: "r0"}
    for i, d in enumerate(devs):
        name = f"n{i}"
        nodes[name] = NodeSpec(name, DEVICE_PROFILES[d], region="r0")
        regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions, bandwidth, latency_s,
                             bandwidth, latency_s)
    return ClusterSpec(nodes=nodes, links=links)


def make_serving_cluster(profile: ModelProfile,
                         devs: Sequence[str] = ("A100", "L4", "T4"),
                         force_stages: int = 0,
                         param_frac: float = 0.5) -> ClusterSpec:
    """Small full-mesh heterogeneous cluster for the serving drivers.

    With ``force_stages`` the per-node VRAM is derated so no node can hold
    more than ``ceil(num_layers / force_stages)`` layers under the planner's
    ``param_frac`` VRAM convention — the MILP then *must* split the model
    into at least that many pipeline stages.
    """
    nodes: Dict[str, NodeSpec] = {}
    regions: Dict[str, str] = {COORDINATOR: "r0"}
    for i, d in enumerate(devs):
        dev = DEVICE_PROFILES[d.strip()]
        if force_stages > 0:
            cap = -(-profile.num_layers // force_stages)
            dev = dataclasses.replace(
                dev,
                vram_bytes=(cap + 0.5) * profile.layer_param_bytes / param_frac)
        name = f"n{i}"
        nodes[name] = NodeSpec(name, dev, region="r0")
        regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions, 10e9 / 8, 1e-3,
                             10e9 / 8, 1e-3)
    return ClusterSpec(nodes=nodes, links=links)


def make_single_cluster(seed_counts: Optional[Mapping[str, int]] = None) -> ClusterSpec:
    """Paper §5.2 single-cluster: 4×A100 + 8×L4 + 12×T4, 10 Gb/s, <1 ms."""
    counts = dict(seed_counts or {"A100": 4, "L4": 8, "T4": 12})
    nodes: Dict[str, NodeSpec] = {}
    regions: Dict[str, str] = {COORDINATOR: "r0"}
    for dev, n in counts.items():
        for i in range(n):
            name = f"{dev.lower()}-{i}"
            nodes[name] = NodeSpec(name, DEVICE_PROFILES[dev], region="r0")
            regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions,
                             intra_bw=10e9 / 8, intra_lat=1e-3,
                             inter_bw=10e9 / 8, inter_lat=1e-3)
    return ClusterSpec(nodes=nodes, links=links)


def make_distributed_cluster() -> ClusterSpec:
    """Paper §5.2 distributed: 3 regions, 100 Mb/s + 50 ms across regions.

    region r0: 4×A100; r1: 2×L4 + 8×T4; r2: 6×L4 + 4×T4.
    """
    layout = {
        "r0": [("A100", 4)],
        "r1": [("L4", 2), ("T4", 8)],
        "r2": [("L4", 6), ("T4", 4)],
    }
    nodes: Dict[str, NodeSpec] = {}
    regions: Dict[str, str] = {COORDINATOR: "r0"}
    for region, devs in layout.items():
        for dev, n in devs:
            for i in range(n):
                name = f"{region}-{dev.lower()}-{i}"
                nodes[name] = NodeSpec(name, DEVICE_PROFILES[dev], region=region)
                regions[name] = region
    links = _full_mesh_links(list(nodes), regions,
                             intra_bw=10e9 / 8, intra_lat=1e-3,
                             inter_bw=100e6 / 8, inter_lat=50e-3)
    return ClusterSpec(nodes=nodes, links=links)


def make_high_heterogeneity_cluster() -> ClusterSpec:
    """Paper §5.5: 42 nodes, 7 types: 4×A100, 6×V100, 8×L4, 10×T4,
    4×(2×L4), 6×(2×T4), 4×(4×T4)."""
    layout = [
        ("A100", 4, 1), ("V100", 6, 1), ("L4", 8, 1), ("T4", 10, 1),
        ("L4", 4, 2), ("T4", 6, 2), ("T4", 4, 4),
    ]
    nodes: Dict[str, NodeSpec] = {}
    regions: Dict[str, str] = {COORDINATOR: "r0"}
    for dev, n, tp in layout:
        for i in range(n):
            name = f"{dev.lower()}x{tp}-{i}"
            nodes[name] = NodeSpec(name, DEVICE_PROFILES[dev], region="r0", tp_degree=tp)
            regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions,
                             intra_bw=10e9 / 8, intra_lat=1e-3,
                             inter_bw=10e9 / 8, inter_lat=1e-3)
    return ClusterSpec(nodes=nodes, links=links)


def make_tpu_pod_cluster(num_slices: int = 8, chips_per_slice: int = 4,
                         regions: int = 2) -> ClusterSpec:
    """TPU adaptation: heterogeneous mix of v5e slices across regions.

    Half the slices are ``chips_per_slice``-chip, a quarter are 8-chip, and a
    quarter single-chip — mimicking incremental fleet deployment.
    """
    nodes: Dict[str, NodeSpec] = {}
    region_of: Dict[str, str] = {COORDINATOR: "r0"}
    kinds = ["TPUv5e-4", "TPUv5e-8", "TPUv5e", "TPUv5e-4"]
    for i in range(num_slices):
        kind = kinds[i % len(kinds)]
        region = f"r{i % regions}"
        name = f"slice-{i}"
        nodes[name] = NodeSpec(name, DEVICE_PROFILES[kind], region=region)
        region_of[name] = region
    links = _full_mesh_links(list(nodes), region_of,
                             intra_bw=6.25e9, intra_lat=1e-4,
                             inter_bw=100e6 / 8, inter_lat=50e-3)
    return ClusterSpec(nodes=nodes, links=links)
