"""Paper §4: Helix runtime scheduling — per-request pipelines via IWRR.

Every node (including the coordinator) owns an IWRR instance whose candidates
are the nodes reachable through valid connections and whose weights are the
edge flows from the max-flow solution.  Scheduling a request walks IWRR
instances from the coordinator until the pipeline covers all L layers;
*partial inference* (§3.3) means a stage only infers layers not yet inferred.

KV-cache estimation (§4.2): the scheduler tracks per-node KV usage estimates
and masks out nodes above a high-water mark during IWRR selection.

Baselines (§5.7): Swarm scheduling (next stage chosen with probability
proportional to node throughput) and random scheduling.
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .cluster import ClusterSpec, ModelProfile, COORDINATOR
from .graph import ClusterGraph, build_graph, connection_valid
from .placement import LayerRange, Placement


@dataclasses.dataclass(frozen=True)
class PipelineStage:
    node: str
    layers: LayerRange  # layers actually inferred at this stage


@dataclasses.dataclass(frozen=True)
class RequestPipeline:
    stages: Tuple[PipelineStage, ...]

    def validate(self, num_layers: int) -> List[str]:
        problems = []
        cursor = 0
        for st in self.stages:
            if st.layers.start != cursor:
                problems.append(f"stage {st} starts at {st.layers.start}, "
                                f"expected {cursor}")
            cursor = st.layers.end
        if cursor != num_layers:
            problems.append(f"pipeline ends at layer {cursor}, "
                            f"expected {num_layers}")
        return problems

    @property
    def nodes(self) -> Tuple[str, ...]:
        return tuple(s.node for s in self.stages)


class IWRR:
    """Interleaved weighted round-robin [37] over (candidate, weight) pairs.

    Implemented as smooth/interleaved WRR: each query adds ``weight`` to every
    candidate's credit and picks the max-credit unmasked candidate, subtracting
    the total weight — giving interleaving proportional to weights without
    bursts (unlike classic WRR which emits runs of the same candidate).
    """

    def __init__(self, candidates: Sequence[str], weights: Sequence[float]):
        assert len(candidates) == len(weights)
        self.candidates = list(candidates)
        self.weights = [max(0.0, w) for w in weights]
        self.credit = [0.0] * len(candidates)

    def pick(self, masked: Optional[set] = None) -> Optional[str]:
        masked = masked or set()
        total = 0.0
        best_i, best_c = -1, -float("inf")
        for i, (cand, w) in enumerate(zip(self.candidates, self.weights)):
            if w <= 0.0:
                continue
            self.credit[i] += w
            total += w
            if cand in masked:
                continue
            if self.credit[i] > best_c:
                best_c, best_i = self.credit[i], i
        if best_i < 0 or total <= 0.0:
            return None
        self.credit[best_i] -= total
        return self.candidates[best_i]


@dataclasses.dataclass
class KVEstimator:
    """§4.2 scheduler-side KV usage estimate per node.

    ``capacity_tokens[n]`` is how many cached tokens node n can hold (VRAM not
    used by params, divided by per-token KV bytes for the layers it holds).
    ``usage[n]`` is the scheduler's running estimate.
    """

    capacity_tokens: Dict[str, float]
    high_water: float = 0.9
    usage: Dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))

    def masked_nodes(self) -> set:
        return {n for n, cap in self.capacity_tokens.items()
                if cap > 0 and self.usage[n] >= self.high_water * cap}

    def reserve(self, node: str, tokens: float) -> None:
        self.usage[node] += tokens

    def release(self, node: str, tokens: float) -> None:
        self.usage[node] = max(0.0, self.usage[node] - tokens)

    def sync(self, node: str, tokens: float) -> None:
        """Install a node's *measured* KV occupancy (e.g. true ``PagePool``
        usage reported by the serving runtime), replacing the running
        reserve/release estimate — the §4.2 mask then reflects reality
        instead of reservations drifting from actual paged usage."""
        self.usage[node] = max(0.0, tokens)

    @staticmethod
    def from_placement(cluster: ClusterSpec, model: ModelProfile,
                       placement: Placement) -> "KVEstimator":
        caps: Dict[str, float] = {}
        for node, rng in placement.assignment.items():
            vram = cluster.nodes[node].vram_bytes
            free = max(0.0, vram - rng.num_layers * model.layer_param_bytes)
            per_token = model.kv_bytes_per_token_layer * rng.num_layers
            caps[node] = free / per_token if per_token > 0 else float("inf")
        return KVEstimator(capacity_tokens=caps)


class BaseScheduler:
    """Common plumbing: placement + valid-connection topology."""

    def __init__(self, cluster: ClusterSpec, model: ModelProfile,
                 placement: Placement, partial_inference: bool = True,
                 kv_estimator: Optional[KVEstimator] = None):
        self.cluster = cluster
        self.model = model
        self.placement = placement
        self.partial_inference = partial_inference
        self.kv = kv_estimator
        self.graph = build_graph(cluster, model, placement, partial_inference)
        # adjacency in cluster terms
        self.succ: Dict[str, List[str]] = defaultdict(list)
        for (u, v) in self.graph.link_capacity:
            self.succ[u].append(v)
        for u in self.succ:
            self.succ[u].sort()

    # -- pipeline walk -----------------------------------------------------
    def _walk(self, choose) -> RequestPipeline:
        """Walk from coordinator to coordinator, using ``choose(current,
        candidates)`` to pick each hop.  Returns a validated pipeline."""
        L = self.model.num_layers
        stages: List[PipelineStage] = []
        current = COORDINATOR
        inferred = 0
        guard = 0
        while inferred < L:
            guard += 1
            if guard > 10 * len(self.placement.assignment) + 10:
                raise RuntimeError("scheduler failed to build a pipeline "
                                   "(graph may be disconnected)")
            candidates = [v for v in self.succ.get(current, [])
                          if v != COORDINATOR
                          and self.placement.assignment[v].end > inferred
                          and self.placement.assignment[v].start <= inferred]
            nxt = choose(current, candidates)
            if nxt is None:
                raise RuntimeError(f"no candidate from {current} at layer "
                                   f"{inferred}")
            rng = self.placement.assignment[nxt]
            stages.append(PipelineStage(nxt, LayerRange(inferred, rng.end)))
            inferred = rng.end
            current = nxt
        return RequestPipeline(tuple(stages))


class HelixScheduler(BaseScheduler):
    """Max-flow-weighted IWRR per-request pipelines (§4.1)."""

    def __init__(self, cluster: ClusterSpec, model: ModelProfile,
                 placement: Placement, flows: Mapping[Tuple[str, str], float],
                 partial_inference: bool = True,
                 kv_estimator: Optional[KVEstimator] = None):
        super().__init__(cluster, model, placement, partial_inference,
                         kv_estimator)
        self._build_iwrr(flows)

    def _build_iwrr(self, flows: Mapping[Tuple[str, str], float]) -> None:
        """(Re)build per-node IWRR instances from edge flows.  The new table
        is assembled fully before being installed, so concurrent ``schedule``
        calls never observe a half-built state."""
        iwrr: Dict[str, IWRR] = {}
        by_src: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
        for (u, v), f in flows.items():
            if v != COORDINATOR and f > 1e-9:
                by_src[u].append((v, f))
        for u, cands in by_src.items():
            cands.sort()
            iwrr[u] = IWRR([c for c, _ in cands], [w for _, w in cands])
        self.flows = dict(flows)
        self._iwrr = iwrr

    def schedule(self, prompt_tokens: int = 0) -> RequestPipeline:
        masked = self.kv.masked_nodes() if self.kv else set()

        def choose(current: str, candidates: List[str]) -> Optional[str]:
            inst = self._iwrr.get(current)
            if inst is None:
                return None
            # IWRR over flow-positive candidates, skipping KV-masked nodes
            # and nodes that can't continue this request.
            bad = masked | (set(inst.candidates) - set(candidates))
            pick = inst.pick(masked=bad)
            if pick is None and candidates:
                # all flow-candidates masked: fall back to least-loaded valid
                pick = min(candidates,
                           key=lambda n: self.kv.usage[n] / max(self.kv.capacity_tokens.get(n, 1), 1)
                           if self.kv else 0.0)
            return pick

        pipe = self._walk(choose)
        if self.kv and prompt_tokens:
            for st in pipe.stages:
                self.kv.reserve(st.node, prompt_tokens)
        return pipe

    def finish(self, pipeline: RequestPipeline, total_tokens: int) -> None:
        """Release KV reservation when a request completes."""
        if self.kv:
            for st in pipeline.stages:
                self.kv.release(st.node, total_tokens)

    def update_weights(self, flows: Mapping[Tuple[str, str], float]) -> None:
        """Atomically swap IWRR weights (used by elastic replanning) without
        rebuilding the topology graph or the KV estimator."""
        self._build_iwrr(flows)


class SwarmScheduler(BaseScheduler):
    """Baseline: next node chosen with probability proportional to its
    inference throughput (SWARM [31] routing, adapted to inference)."""

    def __init__(self, *args, seed: int = 0, **kwargs):
        super().__init__(*args, **kwargs)
        self._rng = random.Random(seed)

    def schedule(self, prompt_tokens: int = 0) -> RequestPipeline:
        def choose(current: str, candidates: List[str]) -> Optional[str]:
            if not candidates:
                return None
            weights = [self.graph.node_capacity.get(c, 0.0) + 1e-9
                       for c in candidates]
            return self._rng.choices(candidates, weights=weights, k=1)[0]
        return self._walk(choose)

    def finish(self, pipeline: RequestPipeline, total_tokens: int) -> None:
        pass


class RandomScheduler(BaseScheduler):
    """Baseline: uniformly random next node."""

    def __init__(self, *args, seed: int = 0, **kwargs):
        super().__init__(*args, **kwargs)
        self._rng = random.Random(seed)

    def schedule(self, prompt_tokens: int = 0) -> RequestPipeline:
        def choose(current: str, candidates: List[str]) -> Optional[str]:
            if not candidates:
                return None
            return self._rng.choice(candidates)
        return self._walk(choose)

    def finish(self, pipeline: RequestPipeline, total_tokens: int) -> None:
        pass
