"""Flow-guided local search (FGLS) — beyond-paper placement refinement.

The paper's MILP needs a commercial solver (Gurobi) to close large instances;
HiGHS (our offline substitute) often stalls on the connection-validity
big-M structure.  FGLS is a fast anytime refiner that works directly with the
exact evaluation function (preflow-push max flow on the *full* graph):

  repeat:
    1. evaluate placement, locate the bottleneck (min-capacity layer window
       and saturated nodes/links in the max-flow solution)
    2. propose moves for a few nodes: shift the layer window left/right,
       grow/shrink it (within VRAM), or re-anchor it at the bottleneck
    3. keep the best improving move; stop after ``patience`` non-improving
       rounds

Used as (a) a standalone optimizer, and (b) the incumbent provider that
warm-starts the MILP/LNS (§3.4's heuristic-hint reproduced with a stronger
hint).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

from .cluster import ClusterSpec, ModelProfile
from .graph import placement_throughput
from .placement import LayerRange, Placement


@dataclasses.dataclass
class FGLSOptions:
    rounds: int = 60
    patience: int = 10
    moves_per_round: int = 24
    partial_inference: bool = True
    param_frac: float = 0.5
    seed: int = 0


def _propose_moves(cluster: ClusterSpec, model: ModelProfile,
                   placement: Placement, node: str, k_max: int,
                   bottleneck_layer: int) -> List[LayerRange]:
    rng = placement.assignment[node]
    L = model.num_layers
    out = []
    n = rng.num_layers
    # shift window
    for delta in (-2, -1, 1, 2):
        s = rng.start + delta
        if 0 <= s and s + n <= L:
            out.append(LayerRange(s, s + n))
    # grow / shrink
    if n + 1 <= k_max and rng.end + 1 <= L:
        out.append(LayerRange(rng.start, rng.end + 1))
    if n + 1 <= k_max and rng.start - 1 >= 0:
        out.append(LayerRange(rng.start - 1, rng.end))
    if n > 1:
        out.append(LayerRange(rng.start, rng.end - 1))
        out.append(LayerRange(rng.start + 1, rng.end))
    # re-anchor at the bottleneck
    s = max(0, min(L - n, bottleneck_layer - n // 2))
    out.append(LayerRange(s, s + n))
    return [r for r in out if r != rng]


def refine_placement(cluster: ClusterSpec, model: ModelProfile,
                     placement: Placement,
                     options: Optional[FGLSOptions] = None
                     ) -> Tuple[Placement, float, List[Dict]]:
    """Refine ``placement``; returns (best placement, throughput, history)."""
    options = options or FGLSOptions()
    rng = random.Random(options.seed)
    k_max = {n: max(1, cluster.max_layers_on(n, model, options.param_frac))
             for n in placement.assignment}

    best = Placement(dict(placement.assignment), placement.num_layers,
                     meta=dict(placement.meta))
    best_val = placement_throughput(cluster, model, best,
                                    options.partial_inference)
    history = [{"round": -1, "throughput": best_val}]
    stale = 0
    nodes = sorted(placement.assignment)

    for rnd in range(options.rounds):
        if stale >= options.patience:
            break
        per_layer = best.layer_compute(cluster, model)
        bottleneck = min(range(len(per_layer)), key=lambda l: per_layer[l])
        # candidate (node, new_range) moves, biased toward low-capacity nodes
        weights = []
        for n in nodes:
            r = best.assignment[n]
            mid = (r.start + r.end) / 2
            dist = abs(mid - bottleneck) + 1
            weights.append(1.0 / dist)
        moves: List[Tuple[str, LayerRange]] = []
        for _ in range(options.moves_per_round):
            node = rng.choices(nodes, weights=weights, k=1)[0]
            props = _propose_moves(cluster, model, best, node, k_max[node],
                                   bottleneck)
            if props:
                moves.append((node, rng.choice(props)))
        improved = False
        for node, new_range in moves:
            trial = dict(best.assignment)
            trial[node] = new_range
            cand = Placement(trial, best.num_layers, meta={"method": "fgls"})
            if cand.validate():
                continue
            val = placement_throughput(cluster, model, cand,
                                       options.partial_inference)
            if val > best_val * (1 + 1e-9):
                best, best_val = cand, val
                improved = True
        history.append({"round": rnd, "throughput": best_val})
        stale = 0 if improved else stale + 1
    best.meta["method"] = f"fgls({placement.meta.get('method', '?')})"
    return best, best_val, history
