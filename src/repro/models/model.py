"""Model assembly: config -> param specs, train forward, prefill, decode.

The layer stack is ``prologue + pattern * repeats``; the repeated part runs
under ``lax.scan`` with params stacked on a leading "layers" axis, keeping
compiled HLO size independent of depth.  Encoder-decoder (whisper) adds an
encoder stack and per-decoder-block cross-attention.

API:
  param_specs(cfg)                        ParamSpec tree
  init(cfg, key)                          materialized params
  forward(cfg, params, tokens, ...)       logits (+ aux) — training/scoring
  prefill(cfg, params, tokens, ...)       logits, caches
  decode_step(cfg, params, token, caches, pos)  logits, new caches
  init_caches(cfg, batch, max_len)        cache pytree for decode
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import BlockSpec, ModelConfig
from .attention import (attn_spec, cross_attn_spec, gqa_cache_init, gqa_decode,
                        gqa_prefill, mla_cache_init, mla_decode, mla_prefill)
from .common import (ParamSpec, apply_norm, init_params, norm_spec)
from .moe import ffn_apply, ffn_spec, moe_apply, moe_spec
from .ssm import (mamba_decode, mamba_prefill, mamba_spec, mamba_state_init,
                  mlstm_decode, mlstm_prefill, mlstm_spec, mlstm_state_init,
                  slstm_decode, slstm_prefill, slstm_spec, slstm_state_init)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _block_spec(cfg: ModelConfig, b: BlockSpec, decoder: bool) -> Dict:
    spec: Dict[str, Any] = {"norm1": norm_spec(cfg)}
    if b.kind == "attn":
        spec["mix"] = attn_spec(cfg)
    elif b.kind == "mamba":
        spec["mix"] = mamba_spec(cfg)
    elif b.kind == "mlstm":
        spec["mix"] = mlstm_spec(cfg)
    elif b.kind == "slstm":
        spec["mix"] = slstm_spec(cfg)
    else:
        raise ValueError(b.kind)
    if decoder and cfg.is_encoder_decoder:
        spec["cross_norm"] = norm_spec(cfg)
        spec["cross"] = cross_attn_spec(cfg)
    if b.moe:
        spec["norm2"] = norm_spec(cfg)
        spec["moe"] = moe_spec(cfg)
    elif cfg.d_ff > 0:
        spec["norm2"] = norm_spec(cfg)
        spec["ffn"] = ffn_spec(cfg)
    return spec


def _stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            init=s.init, scale=s.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"),
                                     scale=0.02)
    if cfg.prologue:
        specs["prologue"] = [
            _block_spec(cfg, b, decoder=True) for b in cfg.prologue]
    specs["super"] = _stack_specs(
        {f"pos{i}": _block_spec(cfg, b, decoder=True)
         for i, b in enumerate(cfg.pattern)}, cfg.repeats)
    if cfg.is_encoder_decoder:
        enc_block = _block_spec(
            cfg, BlockSpec(kind="attn", attn="full"), decoder=False)
        specs["encoder"] = {
            "pos_embed": ParamSpec((cfg.max_source_positions, d),
                                   (None, "embed"), scale=0.02),
            "blocks": _stack_specs(enc_block, cfg.encoder_layers),
            "final_norm": norm_spec(cfg),
        }
        specs["dec_pos_embed"] = ParamSpec((cfg.max_position, d),
                                           (None, "embed"), scale=0.02)
    return specs


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(param_specs(cfg), key, cfg.param_dtype)


# ---------------------------------------------------------------------------
# Block application (prefill / train path)
# ---------------------------------------------------------------------------

def _apply_block(cfg, b: BlockSpec, p, h, positions, enc_out,
                 skip_masked_chunks=False, collect_cache=False):
    aux = jnp.zeros((), jnp.float32)
    hn = apply_norm(cfg, p["norm1"], h)
    window = b.window if b.attn in ("swa", "local") else 0
    cache = None
    if b.kind == "attn":
        if cfg.mla_kv_lora_rank:
            out, cache = mla_prefill(cfg, p["mix"], hn, positions,
                                     skip_masked_chunks=skip_masked_chunks)
        else:
            out, cache = gqa_prefill(cfg, p["mix"], hn, positions,
                                     causal=True, window=window,
                                     skip_masked_chunks=skip_masked_chunks)
    elif b.kind == "mamba":
        out, cache = mamba_prefill(cfg, p["mix"], hn)
    elif b.kind == "mlstm":
        out, cache = mlstm_prefill(cfg, p["mix"], hn)
    elif b.kind == "slstm":
        out, cache = slstm_prefill(cfg, p["mix"], hn)
    h = h + out
    if "cross" in p and enc_out is not None:
        hn = apply_norm(cfg, p["cross_norm"], h)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["k"])
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["v"])
        out, _ = gqa_prefill(cfg, p["cross"], hn, positions,
                             cross_kv=(ck, cv))
        h = h + out
        if collect_cache:
            cache = {"self": cache, "cross": (ck, cv)}
    if "moe" in p:
        hn = apply_norm(cfg, p["norm2"], h)
        out, moe_aux = moe_apply(cfg, p["moe"], hn)
        aux = aux + moe_aux["aux_loss"]
        h = h + out
    elif "ffn" in p:
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + ffn_apply(p["ffn"], hn)
    return h, cache, aux


def _embed(cfg, params, tokens, positions):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.is_encoder_decoder:
        h = h + jnp.take(params["dec_pos_embed"],
                         jnp.minimum(positions, cfg.max_position - 1), axis=0)
    return h


def _logits(cfg, params, h):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"])


def encode(cfg, params, frames):
    """Whisper encoder over stubbed frame embeddings (B, T_src, d)."""
    enc = params["encoder"]
    T = frames.shape[1]
    h = frames + enc["pos_embed"][:T][None]
    positions = jnp.broadcast_to(jnp.arange(T), frames.shape[:2])

    def step(h, p):
        hn = apply_norm(cfg, p["norm1"], h)
        out, _ = gqa_prefill(cfg, p["mix"], hn, positions, causal=False)
        h = h + out
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + ffn_apply(p["ffn"], hn)
        return h, None

    h, _ = jax.lax.scan(step, h, enc["blocks"])
    return apply_norm(cfg, enc["final_norm"], h)


# ---------------------------------------------------------------------------
# Train / scoring forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, *, encoder_frames=None,
            skip_masked_chunks=False) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B,S) int32 -> (logits (B,S,V), aux_loss scalar)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = encode(cfg, params, encoder_frames) \
        if cfg.is_encoder_decoder else None
    h = _embed(cfg, params, tokens, positions)
    aux_total = jnp.zeros((), jnp.float32)

    for i, b in enumerate(cfg.prologue):
        h, _, aux = _apply_block(cfg, b, params["prologue"][i], h, positions,
                                 enc_out, skip_masked_chunks)
        aux_total += aux

    def superblock(carry, layer_params):
        h, aux_acc = carry
        for i, b in enumerate(cfg.pattern):
            h, _, aux = _apply_block(cfg, b, layer_params[f"pos{i}"], h,
                                     positions, enc_out, skip_masked_chunks)
            aux_acc = aux_acc + aux
        return (h, aux_acc), None

    (h, aux_total), _ = jax.lax.scan(superblock, (h, aux_total),
                                     params["super"])
    h = apply_norm(cfg, params["final_norm"], h)
    return _logits(cfg, params, h), aux_total


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            aux_weight: float = 0.01,
            skip_masked_chunks: bool = False) -> Tuple[jax.Array, Dict]:
    """batch: tokens (B,S), labels (B,S) with -100 = ignore,
    optional encoder_frames."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          encoder_frames=batch.get("encoder_frames"),
                          skip_masked_chunks=skip_masked_chunks)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux,
                  "tokens": denom.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------

def _cache_init_for_block(cfg, b: BlockSpec, batch, max_len, dtype,
                          src_len: Optional[int] = None):
    window = b.window if b.attn in ("swa", "local") else 0
    if b.kind == "attn":
        if cfg.mla_kv_lora_rank:
            c = mla_cache_init(cfg, batch, max_len, dtype)
        else:
            c = gqa_cache_init(cfg, batch, max_len, window, dtype)
        if cfg.is_encoder_decoder:
            nh, hd = cfg.num_heads, cfg.resolved_head_dim
            T = src_len or cfg.max_source_positions
            c = {"self": c,
                 "cross": (jnp.zeros((batch, T, nh, hd), dtype),
                           jnp.zeros((batch, T, nh, hd), dtype))}
        return c
    if b.kind == "mamba":
        return mamba_state_init(cfg, batch, dtype)
    if b.kind == "mlstm":
        return mlstm_state_init(cfg, batch, dtype)
    if b.kind == "slstm":
        return slstm_state_init(cfg, batch, dtype)
    raise ValueError(b.kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                src_len: Optional[int] = None):
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    caches: Dict[str, Any] = {}
    if cfg.prologue:
        caches["prologue"] = [
            _cache_init_for_block(cfg, b, batch, max_len, dtype, src_len)
            for b in cfg.prologue]
    per_pos = {f"pos{i}": _cache_init_for_block(cfg, b, batch, max_len, dtype,
                                                src_len)
               for i, b in enumerate(cfg.pattern)}
    caches["super"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape), per_pos)
    return caches


def _cache_axes_for_block(cfg, b: BlockSpec):
    """Logical-axes tree mirroring _cache_init_for_block (for sharding)."""
    if b.kind == "attn":
        if cfg.mla_kv_lora_rank:
            c = {"c": ("batch", "seq", "lora"),
                 "r": ("batch", "seq", None),
                 "pos": ("batch", "seq")}
        else:
            c = {"k": ("batch", "seq", "kv_heads", "head_dim"),
                 "v": ("batch", "seq", "kv_heads", "head_dim"),
                 "pos": ("batch", "seq")}
        if cfg.is_encoder_decoder:
            cross = (("batch", None, "heads", "head_dim"),
                     ("batch", None, "heads", "head_dim"))
            c = {"self": c, "cross": cross}
        return c
    if b.kind == "mamba":
        return {"h": ("batch", "ff", "state"),
                "conv": ("batch", "conv", "ff")}
    if b.kind == "mlstm":
        return {"C": ("batch", "heads", "head_dim", None),
                "n": ("batch", "heads", "head_dim"),
                "m": ("batch", "heads")}
    if b.kind == "slstm":
        return {"c": ("batch", "embed"), "n": ("batch", "embed"),
                "m": ("batch", "embed"), "h": ("batch", "embed")}
    raise ValueError(b.kind)


def cache_axes(cfg: ModelConfig):
    """Logical axes matching the init_caches structure (leading "layers"
    axis on the stacked super-block caches)."""
    out: Dict[str, Any] = {}
    if cfg.prologue:
        out["prologue"] = [
            _cache_axes_for_block(cfg, b) for b in cfg.prologue]
    per_pos = {f"pos{i}": _cache_axes_for_block(cfg, b)
               for i, b in enumerate(cfg.pattern)}
    out["super"] = jax.tree.map(
        lambda ax: ("layers",) + tuple(ax), per_pos,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return out


def _apply_block_decode(cfg, b: BlockSpec, p, h, cache, cache_pos, enc_out):
    hn = apply_norm(cfg, p["norm1"], h)
    window = b.window if b.attn in ("swa", "local") else 0
    self_cache = cache["self"] if (cfg.is_encoder_decoder
                                   and b.kind == "attn") else cache
    if b.kind == "attn":
        if cfg.mla_kv_lora_rank:
            out, new_cache = mla_decode(cfg, p["mix"], hn, self_cache, cache_pos)
        else:
            out, new_cache = gqa_decode(cfg, p["mix"], hn, self_cache,
                                        cache_pos, window=window)
    elif b.kind == "mamba":
        out, new_cache = mamba_decode(cfg, p["mix"], hn, cache)
    elif b.kind == "mlstm":
        out, new_cache = mlstm_decode(cfg, p["mix"], hn, cache)
    elif b.kind == "slstm":
        out, new_cache = slstm_decode(cfg, p["mix"], hn, cache)
    h = h + out
    if "cross" in p and b.kind == "attn" and cfg.is_encoder_decoder:
        hn = apply_norm(cfg, p["cross_norm"], h)
        out, _ = gqa_decode(cfg, p["cross"], hn, None, cache_pos,
                            cross_kv=cache["cross"])
        h = h + out
        new_cache = {"self": new_cache, "cross": cache["cross"]}
    if "moe" in p:
        hn = apply_norm(cfg, p["norm2"], h)
        out, _ = moe_apply(cfg, p["moe"], hn)
        h = h + out
    elif "ffn" in p:
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + ffn_apply(p["ffn"], hn)
    return h, new_cache


def decode_step(cfg: ModelConfig, params, tokens, caches, cache_pos):
    """One autoregressive step.  tokens: (B,) int32; cache_pos: (B,) int32
    (absolute position of this token).  Returns (logits (B,V), new caches)."""
    B = tokens.shape[0]
    positions = cache_pos[:, None]
    h = _embed(cfg, params, tokens[:, None], positions)

    new_caches: Dict[str, Any] = {}
    if cfg.prologue:
        new_caches["prologue"] = []
        for i, b in enumerate(cfg.prologue):
            h, nc = _apply_block_decode(cfg, b, params["prologue"][i], h,
                                        caches["prologue"][i], cache_pos, None)
            new_caches["prologue"].append(nc)

    def superblock(h, xs):
        layer_params, layer_cache = xs
        new_layer_cache = {}
        for i, b in enumerate(cfg.pattern):
            h, nc = _apply_block_decode(cfg, b, layer_params[f"pos{i}"], h,
                                        layer_cache[f"pos{i}"], cache_pos,
                                        None)
            new_layer_cache[f"pos{i}"] = nc
        return h, new_layer_cache

    h, new_super = jax.lax.scan(superblock, h,
                                (params["super"], caches["super"]))
    new_caches["super"] = new_super
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _logits(cfg, params, h)[:, 0]
    return logits, new_caches


def fill_prefill_cache(cfg: ModelConfig, b: BlockSpec, raw_cache, batch: int,
                       seq_len: int, max_len: int, dtype):
    """Convert one block's prefill outputs (full k/v or final state) into the
    decode cache layout (ring/dense buffers sized max_len)."""
    B, S = batch, seq_len
    window = b.window if b.attn in ("swa", "local") else 0
    if b.kind != "attn":
        return raw_cache
    if cfg.mla_kv_lora_rank:
        c_kv, k_rope = raw_cache
        tgt = mla_cache_init(cfg, B, max_len, dtype)
        n = min(S, max_len)
        tgt["c"] = tgt["c"].at[:, :n].set(c_kv[:, -n:])
        tgt["r"] = tgt["r"].at[:, :n].set(k_rope[:, -n:])
        pos_vals = jnp.broadcast_to(jnp.arange(S)[-n:], (B, n))
        tgt["pos"] = tgt["pos"].at[:, :n].set(pos_vals)
        return tgt
    inner = raw_cache["self"] if isinstance(raw_cache, dict) and \
        "self" in raw_cache else raw_cache
    k, v = inner
    tgt = gqa_cache_init(cfg, B, max_len, window, dtype)
    W = tgt["k"].shape[1]
    n = min(S, W)
    # ring layout: token at absolute pos p sits at slot p % W
    last_pos = jnp.arange(S - n, S)
    slots = (last_pos % W) if window else last_pos
    tgt["k"] = tgt["k"].at[:, slots].set(k[:, -n:])
    tgt["v"] = tgt["v"].at[:, slots].set(v[:, -n:])
    tgt["pos"] = tgt["pos"].at[:, slots].set(
        jnp.broadcast_to(last_pos, (B, n)))
    out = tgt
    if isinstance(raw_cache, dict) and "cross" in raw_cache:
        # keep the encoder length static/unpadded: zero-padded slots
        # would receive softmax mass at decode time
        out = {"self": tgt, "cross": raw_cache["cross"]}
    return out


def prefill(cfg: ModelConfig, params, tokens, *, max_len: Optional[int] = None,
            encoder_frames=None, skip_masked_chunks=False):
    """Process the prompt, returning (last-token logits, caches) ready for
    decode at position S.  tokens: (B,S)."""
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = encode(cfg, params, encoder_frames) \
        if cfg.is_encoder_decoder else None
    h = _embed(cfg, params, tokens, positions)
    dtype = h.dtype

    def fill_cache(b: BlockSpec, raw_cache):
        return fill_prefill_cache(cfg, b, raw_cache, B, S, max_len, dtype)

    caches: Dict[str, Any] = {}
    if cfg.prologue:
        caches["prologue"] = []
        for i, b in enumerate(cfg.prologue):
            h, raw, _ = _apply_block(cfg, b, params["prologue"][i], h,
                                     positions, enc_out, skip_masked_chunks,
                                     collect_cache=True)
            caches["prologue"].append(fill_cache(b, raw))

    def superblock(h, layer_params):
        raws = {}
        for i, b in enumerate(cfg.pattern):
            h, raw, _ = _apply_block(cfg, b, layer_params[f"pos{i}"], h,
                                     positions, enc_out, skip_masked_chunks,
                                     collect_cache=True)
            raws[f"pos{i}"] = fill_cache(b, raw)
        return h, raws

    h, super_caches = jax.lax.scan(superblock, h, params["super"])
    caches["super"] = super_caches
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _logits(cfg, params, h[:, -1:])[:, 0]
    return logits, caches
