"""Pure-JAX model zoo covering the 10 assigned architectures."""
from .model import (decode_step, encode, forward, init, init_caches, loss_fn,
                    param_specs, prefill)
from .paged import (all_blocks_paged, decode_step_paged, init_caches_paged,
                    num_paged_layers, prefill_chunk_paged)
from .stage import (stage_blocks, stage_cache_init, stage_decode,
                    stage_num_paged_layers, stage_params, stage_prefill)
from .common import abstract_shapes, init_params, logical_axes, ParamSpec
