"""Dense (SwiGLU) FFN and token-choice top-k MoE.

MoE uses scatter-based dispatch into per-expert capacity buffers
(E, C, d) so experts shard over the "model"/expert mesh axis (EP) and the
expert matmuls stay dense einsums (MXU-friendly):

  router -> top-k -> position-in-expert (cumsum over one-hot) ->
  scatter tokens into (E, C, d) -> expert SwiGLU einsum -> gather back.

Tokens past capacity C are dropped (standard GShard behaviour); capacity
factor is configurable and counted in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, silu
from .partition import constrain


# ---------------------------------------------------------------------------
# Scatter-free routing primitive
# ---------------------------------------------------------------------------
# A batched gather whose transpose is expressed as ANOTHER gather (the caller
# supplies the inverse mapping).  jax's take_along_axis VJP is a scatter-add;
# GSPMD replicates scatter operands, which at 398B scale turns MoE dispatch
# gradients into full-residual-stream all-reduces (EXPERIMENTS §Perf cell A).
# Dispatch (tokens->capacity slots) and combine (slots->tokens) are mutual
# inverses, so both directions stay shard-local gathers.

@jax.custom_vjp
def inverse_gather(x, idx, inv_idx, inv_valid):
    """x: (G,M,D); idx: (G,P) -> (G,P,D); rows with idx clipped/invalid must
    be masked by the caller.  inv_idx: (G,M) position of each x-row in the
    output (arbitrary where inv_valid is False)."""
    return jnp.take_along_axis(x, idx[..., None], axis=1)


def _inverse_gather_fwd(x, idx, inv_idx, inv_valid):
    return inverse_gather(x, idx, inv_idx, inv_valid), (
        idx, inv_idx, inv_valid)


def _inverse_gather_bwd(res, g):
    idx, inv_idx, inv_valid = res
    gx = jnp.take_along_axis(g, inv_idx[..., None], axis=1)
    gx = jnp.where(inv_valid[..., None], gx, 0)
    return gx, None, None, None


inverse_gather.defvjp(_inverse_gather_fwd, _inverse_gather_bwd)


# ---------------------------------------------------------------------------
# Dense SwiGLU FFN
# ---------------------------------------------------------------------------

def ffn_spec(cfg, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    spec = {
        "w_up": ParamSpec((d, ff), ("embed", "ff")),
        "w_down": ParamSpec((ff, d), ("ff", "embed")),
    }
    if getattr(cfg, "mlp_kind", "gated") == "gated":
        spec["w_gate"] = ParamSpec((d, ff), ("embed", "ff"))
    return spec


def ffn_apply(params: Dict, x: jax.Array) -> jax.Array:
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    if "w_gate" in params:  # SwiGLU
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = silu(g) * u
    else:                   # plain GELU
        h = jax.nn.gelu(u)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_spec(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.moe_num_experts
    spec = {
        "router": ParamSpec((d, E), ("embed", "experts"), scale=0.02),
        "w_gate": ParamSpec((E, d, ff), ("experts", "embed", "ff")),
        "w_up": ParamSpec((E, d, ff), ("experts", "embed", "ff")),
        "w_down": ParamSpec((E, ff, d), ("experts", "ff", "embed")),
    }
    if cfg.moe_num_shared:
        shared_ff = ff * cfg.moe_num_shared
        spec["shared"] = {
            "w_gate": ParamSpec((d, shared_ff), ("embed", "ff")),
            "w_up": ParamSpec((d, shared_ff), ("embed", "ff")),
            "w_down": ParamSpec((shared_ff, d), ("ff", "embed")),
        }
    return spec


def moe_apply(cfg, params: Dict, x: jax.Array,
              capacity_factor: Optional[float] = None) -> Tuple[jax.Array, Dict]:
    """x: (B,S,d) -> (B,S,d), aux dict (load-balance stats/loss).

    Token-choice top-k with normalized softmax gates and capacity dropping.
    Decode steps (S == 1) get drop-free capacity (C = T): token counts are
    tiny and drops would corrupt single-token outputs.

    ``cfg.moe_groups`` > 1 enables GShard-style group-local dispatch: tokens
    split into G groups (aligned with the data shards), each with its own
    capacity buffer — the dispatch scatter stays shard-local and the expert
    einsums never psum capacity-buffer-sized partials across the FSDP axis
    (the difference is TBs of all-reduce at 398B scale; see EXPERIMENTS §Perf
    cell A).
    """
    B, S, d = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    T = B * S
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_capacity_factor", 1.25)
    if S == 1 and getattr(cfg, "moe_decode_drop_free", True):
        capacity_factor = float(E) / K  # C == T: no drops at decode
    # Group-local mode uses the BATCH dim as the group dim (one sequence ==
    # one group): no reshape touches the sharded batch axis, so GSPMD keeps
    # the group dim on the data shards with zero resharding.
    grouped = bool(getattr(cfg, "moe_groups", 0)) and S > 1
    if grouped:
        G, Tg = B, S
        xt = x
    else:
        G, Tg = 1, T
        xt = x.reshape(1, T, d)

    logits = jnp.einsum("gtd,de->gte", xt,
                        params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    N = Tg * K
    e_flat = expert_idx.reshape(G, N)
    g_flat = gate_vals.reshape(G, N)
    C = max(1, int(Tg * K / E * capacity_factor))

    # --- scatter-free dispatch: sort by expert, batched gathers only ---
    # (GSPMD replicates scatter operands, which at 398B scale turns the
    # dispatch into TB-scale reshards; sort+gather stays group-local.)
    sort_idx = jnp.argsort(e_flat, axis=1, stable=True)       # (G,N)
    counts = (e_flat[:, :, None] == jnp.arange(E)[None, None]).sum(
        axis=1)                                                # (G,E)
    offsets = jnp.cumsum(counts, axis=1) - counts              # (G,E)
    slot_pos = offsets[:, :, None] + jnp.arange(C)[None, None]  # (G,E,C)
    slot_valid = jnp.arange(C)[None, None] < counts[:, :, None]
    slot_pos = jnp.clip(slot_pos, 0, N - 1).reshape(G, E * C)
    src = jnp.take_along_axis(sort_idx, slot_pos, axis=1)       # (G,E*C)
    slot_valid_f = slot_valid.reshape(G, E * C)

    # token->slot inverse mapping (for the scatter-free VJPs): the rank of
    # token-k row n within its expert gives its capacity slot
    rank = jnp.argsort(sort_idx, axis=1)                        # inverse perm
    slot_c = rank - jnp.take_along_axis(offsets, e_flat, axis=1)
    keep = slot_c < C
    flat_idx = e_flat * C + jnp.clip(slot_c, 0, C - 1)          # (G,N)

    x_k = jnp.repeat(xt, K, axis=1)                             # (G,N,d)
    buf = inverse_gather(x_k, src, flat_idx, keep)              # (G,E*C,d)
    buf = jnp.where(slot_valid_f[..., None], buf, 0)
    buf = buf.reshape(G, E, C, d)
    if grouped:
        # EP all-to-all: group-sharded -> expert-sharded (GSPMD lowers the
        # resharding to an all-to-all), run experts local to their weights,
        # then all-to-all back before the (group-local) combine gather.
        buf = constrain(buf, ("batch", None, None, None))
        buf = constrain(buf, (None, "experts", None, None))

    w_gate, w_up, w_down = (params["w_gate"], params["w_up"],
                            params["w_down"])
    if grouped:
        # gather FSDP'd expert weights at use (~400MB/layer) instead of
        # letting the contraction psum capacity-buffer-sized partials
        w_gate = constrain(w_gate, ("experts", None, None))
        w_up = constrain(w_up, ("experts", None, None))
        w_down = constrain(w_down, ("experts", None, None))
    h_g = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    h_u = jnp.einsum("gecd,edf->gecf", buf, w_up)
    h = silu(h_g) * h_u
    if grouped:
        h = constrain(h, (None, "experts", None, "ff"))
    out_buf = jnp.einsum("gecf,efd->gecd", h, w_down)
    if grouped:
        out_buf = constrain(out_buf, (None, "experts", None, None))
        out_buf = constrain(out_buf, ("batch", None, None, None))

    # --- combine: slots -> tokens (inverse of the dispatch gather) ---
    g_flat = jnp.where(keep, g_flat, 0.0)
    y_tok = inverse_gather(out_buf.reshape(G, E * C, d), flat_idx,
                           src, slot_valid_f)                   # (G,N,d)
    y = (y_tok * g_flat[..., None].astype(x.dtype)).reshape(
        G, Tg, K, d).sum(axis=2)

    if cfg.moe_num_shared:
        y = y + ffn_apply(params["shared"], xt)

    # load-balancing aux loss (Switch-style)
    density = probs.mean(axis=(0, 1))                           # (E,)
    sel_frac = counts.astype(jnp.float32).sum(axis=0) / (G * N)  # (E,)
    aux_loss = E * jnp.sum(density * sel_frac)
    dropped = 1.0 - keep.astype(jnp.float32).mean()
    return y.reshape(B, S, d), {"aux_loss": aux_loss, "drop_frac": dropped}
