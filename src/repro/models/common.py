"""Shared model utilities: param specs, norms, RoPE, initializers.

Params are plain nested dicts of jnp arrays.  The single source of truth for
shapes/sharding is ``ParamSpec`` — ``abstract_params`` builds a ParamSpec
tree, ``init_params`` materializes it, and the distribution layer reads the
``axes`` (logical axis names) off the same tree to derive PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (mapped to mesh axes in repro.dist.sharding):
#   batch, seq, embed, heads, kv_heads, head_dim, ff, experts, vocab,
#   layers (scan axis), state, conv, lora, null (replicated)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | alog (mamba A)
    scale: Optional[float] = None   # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def init_param(spec: ParamSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "alog":
        # mamba A: -log-spaced state matrix, stacked per channel
        n = spec.shape[-1]
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                     spec.shape[:-1] + (1,))
        return jnp.log(a).astype(dtype)
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(spec_tree, key: jax.Array, dtype_name: str = "bfloat16"):
    """Materialize a ParamSpec tree into arrays (deterministic per-leaf keys)."""
    dtype = _dtype(dtype_name)
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_shapes(spec_tree, dtype_name: str = "bfloat16"):
    """ShapeDtypeStruct tree for dry-runs (no allocation)."""
    dtype = _dtype(dtype_name)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_axes(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Norms (computed in fp32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm(x: jax.Array, scale: Optional[jax.Array],
              bias: Optional[jax.Array], eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_spec(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="zeros")}
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones"),
                "bias": ParamSpec((d,), ("embed",), init="zeros")}
    if cfg.norm == "nonparam_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg, params: Dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if cfg.norm == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if cfg.norm == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(cfg.norm)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., seq) int32 -> cos/sin of shape (..., seq, dim//2)."""
    half = dim // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, dim); cos/sin: (..., seq, dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)
