"""Attention blocks: GQA (full / sliding-window / local), MLA (DeepSeek),
cross-attention (whisper) — prefill (chunked, flash-style) and decode
(dense-over-cache) paths.

The chunked prefill path is pure JAX (lax.scan online-softmax) so the
multi-pod dry-run lowers on any backend; the Pallas flash kernel
(repro.kernels.flash_attention) is a drop-in replacement on TPU, selected via
``use_kernel="pallas"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, apply_rope, rope_angles

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def attn_spec(cfg) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.resolved_head_dim
    if cfg.mla_kv_lora_rank:
        r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank
        nope, rope_d, vd = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_dim
        nh = cfg.num_heads
        return {
            "q_down": ParamSpec((d, r_q), ("embed", "lora")),
            "q_up": ParamSpec((r_q, nh, nope + rope_d), ("lora", "heads", None)),
            "kv_down": ParamSpec((d, r_kv + rope_d), ("embed", None)),
            "kv_up": ParamSpec((r_kv, nh, nope + vd), ("lora", "heads", None)),
            "o": ParamSpec((nh, vd, d), ("heads", None, "embed")),
        }
    return {
        "q": ParamSpec((d, cfg.num_heads, h), ("embed", "heads", "head_dim")),
        "k": ParamSpec((d, cfg.num_kv_heads, h), ("embed", "kv_heads", "head_dim")),
        "v": ParamSpec((d, cfg.num_kv_heads, h), ("embed", "kv_heads", "head_dim")),
        "o": ParamSpec((cfg.num_heads, h, d), ("heads", "head_dim", "embed")),
    }


def cross_attn_spec(cfg) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.resolved_head_dim
    return {
        "q": ParamSpec((d, cfg.num_heads, h), ("embed", "heads", "head_dim")),
        "k": ParamSpec((d, cfg.num_heads, h), ("embed", "heads", "head_dim")),
        "v": ParamSpec((d, cfg.num_heads, h), ("embed", "heads", "head_dim")),
        "o": ParamSpec((cfg.num_heads, h, d), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# Chunked flash-style attention (pure JAX)
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, window: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      skip_masked_chunks: bool = False) -> jax.Array:
    """q: (B,S,H,D), k/v: (B,S,KH,D) -> (B,S,H,D).  Online-softmax over kv
    chunks; memory O(S * chunk) instead of O(S^2).

    ``skip_masked_chunks``: causal/windowed runs only the kv chunks that can
    be visible to each q chunk (halves causal FLOPs; beyond-paper perf knob).
    """
    B, S, H, D = q.shape
    S_kv = k.shape[1]               # may differ from S (cross-attention)
    KH = k.shape[2]
    G = H // KH
    DV = v.shape[-1]                # may differ from D (MLA)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S_kv)
    # pad S to multiples
    def pad_to(x, c, axis):
        r = (-x.shape[axis]) % c
        if r == 0:
            return x
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, r)
        return jnp.pad(x, pad)

    qp = pad_to(q, q_chunk, 1)
    kp = pad_to(k, kv_chunk, 1)
    vp = pad_to(v, kv_chunk, 1)
    Sq, Sk = qp.shape[1], kp.shape[1]
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / math.sqrt(D)

    qp = qp.reshape(B, nq, q_chunk, KH, G, D)
    kp = kp.reshape(B, nk, kv_chunk, KH, D)
    vp = vp.reshape(B, nk, kv_chunk, KH, DV)

    q_pos = jnp.arange(Sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(Sk).reshape(nk, kv_chunk)
    k_valid = (jnp.arange(Sk) < S_kv).reshape(nk, kv_chunk)

    def q_step(qi):
        qc = qp[:, qi] * scale                     # (B,cq,KH,G,D)
        qpos = q_pos[qi]                           # (cq,)

        def kv_step(carry, ki):
            acc, m, l = carry
            kc, vc = kp[:, ki], vp[:, ki]          # (B,ck,KH,D)
            kpos, kval = k_pos[ki], k_valid[ki]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32)
            mask = kval[None, :]
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KH, G, q_chunk, DV), jnp.float32)
        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)

        if skip_masked_chunks and (causal or window):
            # static bounds per q chunk: kv chunks fully in the future are
            # skipped; with a window, chunks fully before the window too.
            lo = 0
            hi = nk
            q_first, q_last = int(qi) * q_chunk, (int(qi) + 1) * q_chunk - 1
            if causal:
                hi = min(nk, q_last // kv_chunk + 1)
            if window:
                lo = max(0, (q_first - window + 1) // kv_chunk)
            ks = jnp.arange(lo, hi)
        else:
            ks = jnp.arange(nk)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), ks)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out                                  # (B,KH,G,cq,D)

    if skip_masked_chunks and (causal or window):
        outs = [q_step(qi) for qi in range(nq)]     # static unroll (varying bounds)
        out = jnp.stack(outs, axis=0)
    else:
        out = jax.lax.map(q_step, jnp.arange(nq))
    # (nq,B,KH,G,cq,DV) -> (B, S, H, DV)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, DV)
    return out[:, :S].astype(q.dtype)


def _gqa_decode_scores(q, k_cache):
    """q: (B,1,H,D); k_cache: (B,S,KH,D) -> (B,KH,G,S) fp32 scores."""
    B, _, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, D)
    return jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                      preferred_element_type=jnp.float32) / math.sqrt(D)


# ---------------------------------------------------------------------------
# Standard GQA attention block
# ---------------------------------------------------------------------------

def gqa_prefill(cfg, params, x, positions, *, causal=True, window=0,
                cross_kv=None, skip_masked_chunks=False):
    """x: (B,S,d).  Returns (out, cache) where cache=(k,v) with rope applied.

    ``cross_kv``: (k,v) from an encoder — used for whisper cross-attention
    (no causal mask, positions ignored for kv).
    """
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["q"])
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["k"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["v"])
        if cfg.rope_theta > 0:
            cos, sin = rope_angles(positions, cfg.resolved_head_dim,
                                   cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        k, v = cross_kv
        if cfg.rope_theta > 0:
            cos, sin = rope_angles(positions, cfg.resolved_head_dim,
                                   cfg.rope_theta)
            q = apply_rope(q, cos, sin)
        causal = False
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            skip_masked_chunks=skip_masked_chunks)
    out = jnp.einsum("bshk,hkd->bsd", out, params["o"])
    return out, (k, v)


def gqa_decode(cfg, params, x, cache, cache_pos, *, window=0, cross_kv=None):
    """Single-token decode.  x: (B,1,d); cache: dict(k,v,(pos)) ring buffers
    of length W (windowed) or max_len; cache_pos: (B,) absolute position of
    the token being generated.

    Returns (out, new_cache).  Keys in the cache already have rope applied.
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["q"])
    if cross_kv is not None:
        k_cache, v_cache = cross_kv
        scores = _gqa_decode_scores(q, k_cache)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhgs,bshd->bhgd", attn.astype(x.dtype), v_cache)
        ctx = ctx.reshape(B, 1, cfg.num_heads, cfg.resolved_head_dim)
        return jnp.einsum("bshk,hkd->bsd", ctx, params["o"]), cache

    k_new = jnp.einsum("bsd,dhk->bshk", x, params["k"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["v"])
    if cfg.rope_theta > 0:
        cos, sin = rope_angles(cache_pos[:, None], cfg.resolved_head_dim,
                               cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)

    W = cache["k"].shape[1]
    slot = (cache_pos % W) if window else jnp.minimum(cache_pos, W - 1)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0])
    slot_pos = cache["pos"].at[bidx, slot].set(cache_pos)

    scores = _gqa_decode_scores(q, k_cache)
    valid = (slot_pos <= cache_pos[:, None])
    if window:
        valid &= (cache_pos[:, None] - slot_pos < window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgs,bshd->bhgd", attn.astype(x.dtype), v_cache)
    ctx = ctx.reshape(B, 1, cfg.num_heads, cfg.resolved_head_dim)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["o"])
    return out, {"k": k_cache, "v": v_cache, "pos": slot_pos}


# ---------------------------------------------------------------------------
# Paged GQA paths (decode via the Pallas paged_attention kernel)
# ---------------------------------------------------------------------------

def _gqa_qkv_rope(cfg, params, x, positions):
    """Project q/k/v for a chunk and apply rope at absolute ``positions``.
    x: (B,C,d); positions: (B,C) -> q (B,C,H,D), k/v (B,C,KH,D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["v"])
    if cfg.rope_theta > 0:
        cos, sin = rope_angles(positions, cfg.resolved_head_dim,
                               cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_decode_paged(cfg, params, x, k_pages, v_pages, block_table, cache_pos,
                     *, k_scales=None, v_scales=None, interpret=False):
    """Single-token decode against a shared page pool.

    x: (B,1,d); k/v_pages: (P,page,KH,D) pool shared across layers;
    block_table: (B,NP) page ids for this layer; cache_pos: (B,) absolute
    position of the token being generated.  Writes the new K/V into the page
    holding ``cache_pos`` and runs the Pallas paged_attention kernel over the
    sequence's pages.  ``k_scales``/``v_scales``: (P, KH) f32 when the pool
    is int8 (per-page per-kv-head absmax; the append requantizes the touched
    page and the kernel dequantizes in-VMEM).  Returns
    (out, k_pages, v_pages, k_scales, v_scales).
    """
    from ..kernels.paged_attention import paged_attention_op

    B = x.shape[0]
    page = k_pages.shape[1]
    q, k_new, v_new = _gqa_qkv_rope(cfg, params, x, cache_pos[:, None])
    if k_scales is not None:
        from ..kernels.paged_attention import quantized_append
        k_pages, k_scales = quantized_append(k_pages, k_scales, block_table,
                                             cache_pos, k_new)
        v_pages, v_scales = quantized_append(v_pages, v_scales, block_table,
                                             cache_pos, v_new)
    else:
        pid = jnp.take_along_axis(block_table, (cache_pos // page)[:, None],
                                  axis=1)[:, 0]
        off = cache_pos % page
        k_pages = k_pages.at[pid, off].set(k_new[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[pid, off].set(v_new[:, 0].astype(v_pages.dtype))
    ctx = paged_attention_op(q[:, 0], k_pages, v_pages, block_table,
                             cache_pos + 1, k_scales, v_scales,
                             interpret=interpret)
    out = jnp.einsum("bshk,hkd->bsd", ctx[:, None].astype(x.dtype),
                     params["o"])
    return out, k_pages, v_pages, k_scales, v_scales


def gqa_prefill_paged(cfg, params, x, k_pages, v_pages, block_table,
                      positions, *, k_scales=None, v_scales=None,
                      active_blocks=None):
    """Chunked paged prefill: write this chunk's K/V into the pool and attend
    the chunk's queries causally over everything the sequence has written so
    far (earlier chunks included — pure-JAX gather over the block table; the
    Pallas kernel covers the decode side).

    x: (B,C,d); positions: (B,C) absolute positions of the chunk tokens.
    ``active_blocks``: static cap on the gather — only the first
    ``active_blocks`` table entries (>= ceil((pos+C)/page), the pages that
    actually hold tokens) are materialized, instead of the whole per-sequence
    ``NP`` budget; masked-out entries contributed exactly 0 to the softmax
    (NEG_INF underflows), so capping is numerically identical.
    ``k_scales``/``v_scales``: (P, KH) f32 for int8 pools — the chunk is
    appended via page-granular requantization and the gather dequantizes.
    Returns (out (B,C,d), k_pages, v_pages, k_scales, v_scales).
    """
    B, C, d = x.shape
    P, page, KH, D = k_pages.shape
    NP = block_table.shape[1]
    H = cfg.num_heads
    G = H // KH
    nact = NP if active_blocks is None else max(1, min(active_blocks, NP))
    q, k_new, v_new = _gqa_qkv_rope(cfg, params, x, positions)
    if k_scales is not None:
        from ..kernels.paged_attention import (dequantize_kv_pages,
                                               quantized_append)
        k_pages, k_scales = quantized_append(k_pages, k_scales, block_table,
                                             positions[:, 0], k_new)
        v_pages, v_scales = quantized_append(v_pages, v_scales, block_table,
                                             positions[:, 0], v_new)
        bt = block_table[:, :nact]
        k_all = dequantize_kv_pages(k_pages[bt], k_scales[bt], x.dtype)
        v_all = dequantize_kv_pages(v_pages[bt], v_scales[bt], x.dtype)
    else:
        pid = jnp.take_along_axis(block_table, positions // page, axis=1)
        off = positions % page
        k_pages = k_pages.at[pid, off].set(k_new.astype(k_pages.dtype))
        v_pages = v_pages.at[pid, off].set(v_new.astype(v_pages.dtype))
        bt = block_table[:, :nact]
        k_all = k_pages[bt]
        v_all = v_pages[bt]
    k_all = k_all.reshape(B, nact * page, KH, D)
    v_all = v_all.reshape(B, nact * page, KH, D)
    qg = q.reshape(B, C, KH, G, D)
    s = jnp.einsum("bchgd,bshd->bhgcs", qg, k_all,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    kpos = jnp.arange(nact * page)
    mask = kpos[None, None, :] <= positions[:, :, None]        # (B,C,S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhgcs,bshd->bchgd", attn.astype(x.dtype), v_all)
    ctx = ctx.reshape(B, C, H, D)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["o"])
    return out, k_pages, v_pages, k_scales, v_scales


def gqa_cache_init(cfg, batch: int, max_len: int, window: int, dtype):
    W = min(window, max_len) if window else max_len
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, W, kh, hd), dtype),
        "v": jnp.zeros((batch, W, kh, hd), dtype),
        "pos": jnp.full((batch, W), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV cache
# ---------------------------------------------------------------------------

def mla_prefill(cfg, params, x, positions, *, skip_masked_chunks=False):
    B, S, d = x.shape
    nope, rope_d = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim
    vd, nh = cfg.mla_v_dim, cfg.num_heads

    q_lat = jnp.einsum("bsd,dr->bsr", x, params["q_down"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["q_up"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kv = jnp.einsum("bsd,dr->bsr", x, params["kv_down"])
    c_kv, k_rope = kv[..., :cfg.mla_kv_lora_rank], kv[..., cfg.mla_kv_lora_rank:]
    k_up = jnp.einsum("bsr,rhk->bshk", c_kv, params["kv_up"])
    k_nope, v = k_up[..., :nope], k_up[..., nope:]

    cos, sin = rope_angles(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # single shared head
    k_rope_b = jnp.broadcast_to(k_rope, (B, S, nh, rope_d))

    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = chunked_attention(q_cat, k_cat, v, causal=True,
                            skip_masked_chunks=skip_masked_chunks)
    out = jnp.einsum("bshv,hvd->bsd", out, params["o"])
    cache = (c_kv, k_rope[:, :, 0, :])
    return out, cache


def mla_decode(cfg, params, x, cache, cache_pos):
    """Absorbed MLA decode: scores/values computed against the compressed
    cache, with kv_up folded into the query/output (DeepSeek-V2 §"matrix
    absorption") — per-step FLOPs scale with kv_lora_rank, not heads*dim."""
    B = x.shape[0]
    nope, rope_d = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim
    vd, nh = cfg.mla_v_dim, cfg.num_heads
    r = cfg.mla_kv_lora_rank

    q_lat = jnp.einsum("bsd,dr->bsr", x, params["q_down"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["q_up"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_angles(cache_pos[:, None], rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv = jnp.einsum("bsd,dr->bsr", x, params["kv_down"])
    c_new, k_rope_new = kv[..., :r], kv[..., r:]
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], cos, sin)[:, :, 0, :]

    W = cache["c"].shape[1]
    slot = jnp.minimum(cache_pos, W - 1)
    bidx = jnp.arange(B)
    c_cache = cache["c"].at[bidx, slot].set(c_new[:, 0])
    rope_cache = cache["r"].at[bidx, slot].set(k_rope_new[:, 0])
    slot_pos = cache["pos"].at[bidx, slot].set(cache_pos)

    w_uk = params["kv_up"][..., :nope]           # (r, H, nope)
    w_uv = params["kv_up"][..., nope:]           # (r, H, vd)
    q_c = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    s = jnp.einsum("bshr,btr->bhst", q_c, c_cache,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bshp,btp->bhst", q_rope, rope_cache,
                    preferred_element_type=jnp.float32)
    s /= math.sqrt(nope + rope_d)
    valid = slot_pos <= cache_pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhst,btr->bshr", attn.astype(x.dtype), c_cache)
    ctx = jnp.einsum("bshr,rhv->bshv", ctx_c, w_uv)
    out = jnp.einsum("bshv,hvd->bsd", ctx, params["o"])
    return out, {"c": c_cache, "r": rope_cache, "pos": slot_pos}


def mla_cache_init(cfg, batch: int, max_len: int, dtype):
    return {
        "c": jnp.zeros((batch, max_len, cfg.mla_kv_lora_rank), dtype),
        "r": jnp.zeros((batch, max_len, cfg.mla_qk_rope_dim), dtype),
        "pos": jnp.full((batch, max_len), jnp.iinfo(jnp.int32).max, jnp.int32),
    }
