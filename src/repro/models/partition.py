"""Activation-sharding hints for model code.

GSPMD propagates weight shardings well, but some activation layouts need an
explicit nudge (canonical example: the MoE group dim must follow the data
shards or the expert einsums psum capacity-buffer-sized partials).  The
launcher installs (mesh, rules) here; model code calls ``constrain`` with
logical axis names.  When no hints are installed (single-device tests,
engines) it's a no-op.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

_HINTS = {"mesh": None, "rules": None}


def set_mesh_rules(mesh: Optional[Mesh], rules) -> None:
    _HINTS["mesh"] = mesh
    _HINTS["rules"] = rules


def clear() -> None:
    set_mesh_rules(None, None)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]):
    mesh, rules = _HINTS["mesh"], _HINTS["rules"]
    if mesh is None or rules is None:
        return x
    # lazy import: models <- dist.pipeline <- dist <- here would cycle at
    # module load; by constrain time both packages are fully initialized
    from ..dist.sharding import sharding_for
    return jax.lax.with_sharding_constraint(
        x, sharding_for(tuple(x.shape), tuple(logical_axes), rules, mesh))
