"""Stage-level model execution: run a contiguous layer slice of the stack.

Helix's MILP assigns each node a contiguous ``LayerRange``; a *stage engine*
executes only those blocks, receiving token ids (first stage) or incoming
activations and emitting activations (or sampling-ready logits at the final
stage).  This module is the model-side counterpart of
``repro.serving.stage_engine``:

  stage_params(cfg, params, layers)      param slice a stage engine holds
  stage_cache_init[_paged]               per-block decode caches for the slice
  stage_prefill                          prompt pass over the slice (dense)
  stage_decode                           one decode step, batched + row-masked
  stage_prefill_chunk_paged              chunked paged prefill over the slice
  stage_decode_paged                     paged decode over the slice
  stage_absorb_dense_prefill             hybrid: dense prefill K/V -> pages

Per-row entry masking: §3.3 *partial inference* means a request may enter a
node mid-range (layers already inferred upstream are skipped), and per-node
continuous batching mixes requests with different entry layers in one decode
step.  Each block therefore applies only to rows with ``row_start <= layer``;
masked rows pass their hidden state through unchanged.  Masked rows still
write their (meaningless) K/V into their own cache rows / pages — those
entries are never read, because a request's entry layer is fixed for its
lifetime at a node.

Unlike the full-model path, the slice runs as an unrolled Python loop over at
most ``layers.num_layers`` blocks (no ``lax.scan`` over stacked params): each
node holds only its slice, so compiled size stays proportional to the slice.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import BlockSpec, ModelConfig
from ..core.placement import LayerRange
from .common import apply_norm
from .model import (_apply_block, _apply_block_decode, _cache_init_for_block,
                    _embed, _logits, fill_prefill_cache)
from .paged import _block_decode_paged, _block_prefill_paged, is_paged_block


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


# ---------------------------------------------------------------------------
# Slice layout
# ---------------------------------------------------------------------------

def stage_blocks(cfg: ModelConfig, layers: LayerRange
                 ) -> List[Tuple[int, BlockSpec]]:
    """(global layer index, BlockSpec) for every block in the slice."""
    blocks = cfg.blocks
    if not (0 <= layers.start < layers.end <= cfg.num_layers):
        raise ValueError(f"layer range {layers} outside [0, {cfg.num_layers})")
    return [(l, blocks[l]) for l in range(layers.start, layers.end)]


def stage_num_paged_layers(cfg: ModelConfig, layers: LayerRange) -> int:
    return sum(is_paged_block(cfg, b) for _, b in stage_blocks(cfg, layers))


def stage_all_paged(cfg: ModelConfig, layers: LayerRange) -> bool:
    return all(is_paged_block(cfg, b) for _, b in stage_blocks(cfg, layers))


def stage_params(cfg: ModelConfig, params, layers: LayerRange) -> Dict:
    """Extract the param subtree one stage needs: per-block params for
    [start, end) plus the embedding table (first stage, and the last stage
    when embeddings are tied), final norm + LM head (last stage).

    Block params come out of the stacked ``super`` tree as per-layer slices,
    so a node materializes only its share of the repeated stack.
    """
    if cfg.is_encoder_decoder:
        raise NotImplementedError(
            "stage execution covers decoder-only stacks; "
            f"{cfg.name} is encoder-decoder")
    P = len(cfg.prologue)
    pat = max(1, len(cfg.pattern))
    first = layers.start == 0
    last = layers.end == cfg.num_layers
    out: Dict[str, Any] = {"blocks": []}
    for l, _ in stage_blocks(cfg, layers):
        if l < P:
            out["blocks"].append(params["prologue"][l])
        else:
            r, i = divmod(l - P, pat)
            out["blocks"].append(jax.tree.map(lambda x, r=r: x[r],
                                              params["super"][f"pos{i}"]))
    if first or (last and cfg.tie_embeddings):
        out["embed"] = params["embed"]
    if last:
        out["final_norm"] = params["final_norm"]
        if not cfg.tie_embeddings:
            out["lm_head"] = params["lm_head"]
    return out


def stage_cache_init(cfg: ModelConfig, layers: LayerRange, batch: int,
                     max_len: int) -> List:
    """Dense per-block decode caches for the slice (batch-major leaves)."""
    dt = _dtype(cfg)
    return [_cache_init_for_block(cfg, b, batch, max_len, dt)
            for _, b in stage_blocks(cfg, layers)]


def stage_cache_init_paged(cfg: ModelConfig, layers: LayerRange, batch: int,
                           max_len: int) -> List:
    """Like ``stage_cache_init`` but paged blocks hold ``{}`` — their KV
    lives in the node's page pool."""
    dt = _dtype(cfg)
    return [{} if is_paged_block(cfg, b)
            else _cache_init_for_block(cfg, b, batch, max_len, dt)
            for _, b in stage_blocks(cfg, layers)]


# ---------------------------------------------------------------------------
# Dense prefill / decode over the slice
# ---------------------------------------------------------------------------

def stage_prefill(cfg: ModelConfig, sparams, layers: LayerRange, x,
                  entry: int, *, max_len: int):
    """Prompt pass over blocks [entry, layers.end).

    ``entry`` is the request's entry layer at this node (static;
    ``layers.start <= entry < layers.end``).  ``x`` is token ids (B,S) when
    ``entry == 0`` else incoming activations (B,S,d).  Returns
    ``(out, caches)`` where ``out`` is last-token logits (B,V) when the slice
    ends the model, else outgoing activations (B,S,d); ``caches`` covers all
    local blocks (skipped prefix blocks get fresh inits so the pytree matches
    the engine's slot layout).
    """
    last = layers.end == cfg.num_layers
    if entry == 0:
        B, S = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = _embed(cfg, sparams, x, positions)
    else:
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = x
    dt = _dtype(cfg)
    caches: List = []
    for (l, b), p in zip(stage_blocks(cfg, layers), sparams["blocks"]):
        if l < entry:
            caches.append(_cache_init_for_block(cfg, b, B, max_len, dt))
            continue
        h, raw, _ = _apply_block(cfg, b, p, h, positions, None,
                                 collect_cache=True)
        caches.append(fill_prefill_cache(cfg, b, raw, B, S, max_len, dt))
    if last:
        h = apply_norm(cfg, sparams["final_norm"], h)
        return _logits(cfg, sparams, h[:, -1:])[:, 0], caches
    return h, caches


def stage_decode(cfg: ModelConfig, sparams, layers: LayerRange, tok, h_in,
                 row_start, caches, cache_pos):
    """One batched decode step over the slice with per-row entry masking.

    tok: (B,) int32 token ids (consumed only by rows entering at layer 0 —
    possible only when ``layers.start == 0``); h_in: (B,1,d) incoming
    activations; row_start: (B,) int32 entry layer per row; cache_pos: (B,).
    Returns ``(h_out (B,1,d), logits (B,V) | None, new_caches)`` — logits are
    computed iff the slice ends the model.
    """
    positions = cache_pos[:, None]
    if layers.start == 0:
        emb = _embed(cfg, sparams, tok[:, None], positions)
        h = jnp.where((row_start == 0)[:, None, None], emb,
                      h_in.astype(emb.dtype))
    else:
        h = h_in.astype(_dtype(cfg))
    new_caches: List = []
    for (l, b), p, c in zip(stage_blocks(cfg, layers), sparams["blocks"],
                            caches):
        h_new, nc = _apply_block_decode(cfg, b, p, h, c, cache_pos, None)
        h = jnp.where((row_start <= l)[:, None, None], h_new, h)
        new_caches.append(nc)
    logits = None
    if layers.end == cfg.num_layers:
        hn = apply_norm(cfg, sparams["final_norm"], h)
        logits = _logits(cfg, sparams, hn)[:, 0]
    return h, logits, new_caches


# ---------------------------------------------------------------------------
# Paged prefill / decode over the slice
# ---------------------------------------------------------------------------

def stage_prefill_chunk_paged(cfg: ModelConfig, sparams, layers: LayerRange,
                              x, entry: int, start_pos, k_pages, v_pages,
                              tables, *, k_scales=None, v_scales=None,
                              active_blocks=None):
    """Prefill one prompt chunk through the slice, appending K/V to the
    node's pool.  Only valid when every block in [entry, layers.end) is paged
    (use ``stage_prefill`` + ``stage_absorb_dense_prefill`` for hybrids).

    x: (B,C) tokens when ``entry == 0`` else (B,C,d); start_pos: (B,)
    absolute position of x[:, 0]; tables: (n_local_paged, B, NP) block
    tables in local paged-layer order; ``active_blocks``: static gather cap
    (see ``gqa_prefill_paged``).  Returns ``(out, k_pages, v_pages,
    k_scales, v_scales)`` with ``out`` = last-token logits when the slice
    ends the model, else outgoing chunk activations (B,C,d).
    """
    C = x.shape[1]
    positions = start_pos[:, None] + jnp.arange(C)[None, :]
    h = _embed(cfg, sparams, x, positions) if entry == 0 else x
    li = sum(is_paged_block(cfg, b) for l, b in stage_blocks(cfg, layers)
             if l < entry)
    for (l, b), p in zip(stage_blocks(cfg, layers), sparams["blocks"]):
        if l < entry:
            continue
        if not is_paged_block(cfg, b):
            raise ValueError(f"layer {l} of {cfg.name} is not paged; chunked "
                             "stage prefill requires an all-paged slice")
        h, k_pages, v_pages, k_scales, v_scales = _block_prefill_paged(
            cfg, p, h, k_pages, v_pages, k_scales, v_scales, tables[li],
            positions, active_blocks)
        li += 1
    if layers.end == cfg.num_layers:
        h = apply_norm(cfg, sparams["final_norm"], h)
        return (_logits(cfg, sparams, h[:, -1:])[:, 0], k_pages, v_pages,
                k_scales, v_scales)
    return h, k_pages, v_pages, k_scales, v_scales


def stage_decode_paged(cfg: ModelConfig, sparams, layers: LayerRange, tok,
                       h_in, row_start, caches, cache_pos, k_pages, v_pages,
                       tables, *, k_scales=None, v_scales=None,
                       interpret: bool = False):
    """Paged analogue of ``stage_decode``: paged blocks run the Pallas
    paged_attention kernel over their block-table row; other blocks use their
    dense fallback caches.  Returns ``(h_out, logits | None, new_caches,
    k_pages, v_pages, k_scales, v_scales)``."""
    positions = cache_pos[:, None]
    if layers.start == 0:
        emb = _embed(cfg, sparams, tok[:, None], positions)
        h = jnp.where((row_start == 0)[:, None, None], emb,
                      h_in.astype(emb.dtype))
    else:
        h = h_in.astype(_dtype(cfg))
    new_caches: List = []
    li = 0
    for (l, b), p, c in zip(stage_blocks(cfg, layers), sparams["blocks"],
                            caches):
        if is_paged_block(cfg, b):
            h_new, k_pages, v_pages, k_scales, v_scales = _block_decode_paged(
                cfg, p, h, k_pages, v_pages, k_scales, v_scales, tables[li],
                cache_pos, interpret)
            nc: Any = {}
            li += 1
        else:
            h_new, nc = _apply_block_decode(cfg, b, p, h, c, cache_pos, None)
        h = jnp.where((row_start <= l)[:, None, None], h_new, h)
        new_caches.append(nc)
    logits = None
    if layers.end == cfg.num_layers:
        hn = apply_norm(cfg, sparams["final_norm"], h)
        logits = _logits(cfg, sparams, hn)[:, 0]
    return h, logits, new_caches, k_pages, v_pages, k_scales, v_scales


def stage_absorb_dense_prefill(cfg: ModelConfig, layers: LayerRange, caches,
                               k_pages, v_pages, table, slot: int,
                               seq_len: int, page: int, *, k_scales=None,
                               v_scales=None):
    """Move a single-request dense stage prefill's GQA K/V into the pool.

    Hybrid slices prefill single-shot with ``stage_prefill`` (correct at any
    prompt length), then scatter each paged block's K/V into this slot's
    pages and drop those leaves (replaced by ``{}``).  table: host
    (n_local_paged, max_batch, NP) int32.  Int8 pools quantize each
    destination page exactly once.  Returns (caches', k_pages, v_pages,
    k_scales, v_scales)."""
    import numpy as np

    pos = np.arange(seq_len)
    blk, off = pos // page, jnp.asarray(pos % page)
    nblk = -(-seq_len // page)
    out: List = []
    li = 0
    for (l, b), c in zip(stage_blocks(cfg, layers), caches):
        if not is_paged_block(cfg, b):
            out.append(c)
            continue
        if k_scales is not None:
            from ..kernels.paged_attention import quantize_kv_pages
            pids = jnp.asarray(table[li, slot, :nblk])
            pad = nblk * page - seq_len
            KH, D = c["k"].shape[-2:]
            kb = jnp.pad(c["k"][0, :seq_len].astype(jnp.float32),
                         ((0, pad), (0, 0), (0, 0)))
            vb = jnp.pad(c["v"][0, :seq_len].astype(jnp.float32),
                         ((0, pad), (0, 0), (0, 0)))
            kq, ks = quantize_kv_pages(kb.reshape(nblk, page, KH, D))
            vq, vs = quantize_kv_pages(vb.reshape(nblk, page, KH, D))
            k_pages = k_pages.at[pids].set(kq)
            v_pages = v_pages.at[pids].set(vq)
            k_scales = k_scales.at[pids].set(ks)
            v_scales = v_scales.at[pids].set(vs)
        else:
            pids = jnp.asarray(table[li, slot, blk])
            k_pages = k_pages.at[pids, off].set(
                c["k"][0, :seq_len].astype(k_pages.dtype))
            v_pages = v_pages.at[pids, off].set(
                c["v"][0, :seq_len].astype(v_pages.dtype))
        out.append({})
        li += 1
    return out, k_pages, v_pages, k_scales, v_scales
