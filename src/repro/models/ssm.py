"""Recurrent sequence blocks: Mamba (selective SSM), xLSTM (mLSTM / sLSTM).

All three expose the same API shape as attention blocks:
  *_prefill(cfg, params, x)          -> (out, final_state)
  *_decode(cfg, params, x, state)    -> (out, new_state)
  *_state_init(cfg, batch, dtype)    -> state pytree

Recurrences scan over time with the pointwise projections hoisted out of the
scan (bulk einsums), so the scan body is only the state update.
Per-request state is CONSTANT-SIZE — this is what makes the ``long_500k``
shape tractable for ssm/hybrid archs (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, silu


# ---------------------------------------------------------------------------
# Mamba (selective SSM, mamba-1 recurrence)
# ---------------------------------------------------------------------------

def _dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_spec(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state_dim
    w = cfg.ssm_conv_width
    r = _dt_rank(cfg)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ff")),
        "conv_w": ParamSpec((w, di), ("conv", "ff"), scale=0.5),
        "conv_b": ParamSpec((di,), ("ff",), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * N), ("ff", None)),
        "dt_proj": ParamSpec((r, di), (None, "ff")),
        "dt_bias": ParamSpec((di,), ("ff",), init="zeros"),
        "a_log": ParamSpec((di, N), ("ff", "state"), init="alog"),
        "d_skip": ParamSpec((di,), ("ff",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ff", "embed")),
    }


def _mamba_bulk(cfg, params, x):
    """Pointwise (non-recurrent) part: returns per-step scan inputs."""
    di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state_dim
    r = _dt_rank(cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xs, z = xz[..., :di], xz[..., di:]
    return xs, z


def _mamba_conv_full(cfg, params, xs):
    """Causal depthwise conv over (B,S,di)."""
    w = cfg.ssm_conv_width
    pad = jnp.pad(xs, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xs, dtype=jnp.float32)
    for i in range(w):
        out = out + pad[:, i:i + xs.shape[1]].astype(jnp.float32) \
            * params["conv_w"][i].astype(jnp.float32)
    out = out + params["conv_b"].astype(jnp.float32)
    return silu(out).astype(xs.dtype)


def _mamba_ssm_inputs(cfg, params, xc):
    N = cfg.ssm_state_dim
    r = _dt_rank(cfg)
    proj = jnp.einsum("bse,ep->bsp", xc, params["x_proj"])
    dt_r, Bmat, Cmat = proj[..., :r], proj[..., r:r + N], proj[..., r + N:]
    dt = jnp.einsum("bsr,re->bse", dt_r, params["dt_proj"]) + params["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    return dt, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)


def mamba_prefill(cfg, params, x):
    B, S, d = x.shape
    xs, z = _mamba_bulk(cfg, params, x)
    xc = _mamba_conv_full(cfg, params, xs)
    dt, Bm, Cm = _mamba_ssm_inputs(cfg, params, xc)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))           # (di,N)

    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A)                       # (B,di,N)
        dBx = (dt_t * xc_t.astype(jnp.float32))[..., None] * B_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("ben,bn->be", h, C_t)
        return h, y

    h0 = jnp.zeros((B, xs.shape[-1], cfg.ssm_state_dim), jnp.float32)
    xs_t = jnp.swapaxes(xc, 0, 1)
    inputs = (xs_t, jnp.swapaxes(dt, 0, 1), jnp.swapaxes(Bm, 0, 1),
              jnp.swapaxes(Cm, 0, 1))
    h_final, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.swapaxes(ys, 0, 1) + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y * silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    w = cfg.ssm_conv_width
    conv_state = jnp.pad(xs, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1):]
    return out, {"h": h_final, "conv": conv_state}


def mamba_decode(cfg, params, x, state):
    """x: (B,1,d); state: {h: (B,di,N) fp32, conv: (B,w-1,di)}."""
    B = x.shape[0]
    w = cfg.ssm_conv_width
    xs, z = _mamba_bulk(cfg, params, x)                         # (B,1,di)
    window = jnp.concatenate([state["conv"], xs], axis=1)        # (B,w,di)
    xc = jnp.einsum("bwe,we->be", window.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32))
    xc = silu(xc + params["conv_b"].astype(jnp.float32))[:, None, :].astype(x.dtype)
    dt, Bm, Cm = _mamba_ssm_inputs(cfg, params, xc)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0, :, None] * A)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0][:, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("ben,bn->be", h, Cm[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y * silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    return out, {"h": h, "conv": window[:, 1:]}


def mamba_state_init(cfg, batch: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell)
# ---------------------------------------------------------------------------

def mlstm_spec(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    nh = cfg.xlstm_heads
    hd = d // nh
    return {
        "q": ParamSpec((d, nh, hd), ("embed", "heads", "head_dim")),
        "k": ParamSpec((d, nh, hd), ("embed", "heads", "head_dim")),
        "v": ParamSpec((d, nh, hd), ("embed", "heads", "head_dim")),
        "ig": ParamSpec((d, nh), ("embed", "heads"), scale=0.02),
        "fg": ParamSpec((d, nh), ("embed", "heads"), scale=0.02),
        "og": ParamSpec((d, d), ("embed", None)),
        "out_proj": ParamSpec((d, d), (None, "embed")),
    }


def _mlstm_bulk(cfg, params, x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["k"]) / math.sqrt(
        cfg.d_model // cfg.xlstm_heads)
    v = jnp.einsum("bsd,dhk->bshk", x, params["v"])
    ig = jnp.einsum("bsd,dh->bsh", x, params["ig"]).astype(jnp.float32)
    fg = jnp.einsum("bsd,dh->bsh", x, params["fg"]).astype(jnp.float32)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, params["og"]))
    return q, k, v, ig, fg, og


def _mlstm_step(carry, inp):
    C, n, m = carry                               # (B,H,K,V),(B,H,K),(B,H)
    q_t, k_t, v_t, i_t, f_t = inp
    logf = jax.nn.log_sigmoid(f_t)                # stable forget in log space
    m_new = jnp.maximum(logf + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(logf + m - m_new)
    kf = k_t.astype(jnp.float32)
    vf = v_t.astype(jnp.float32)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * kf
    qf = q_t.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_prefill(cfg, params, x):
    B, S, d = x.shape
    nh = cfg.xlstm_heads
    hd = d // nh
    q, k, v, ig, fg, og = _mlstm_bulk(cfg, params, x)
    carry = (jnp.zeros((B, nh, hd, hd), jnp.float32),
             jnp.zeros((B, nh, hd), jnp.float32),
             jnp.full((B, nh), -1e30, jnp.float32))
    sw = lambda a: jnp.swapaxes(a, 0, 1)
    carry, hs = jax.lax.scan(_mlstm_step, carry,
                             (sw(q), sw(k), sw(v), sw(ig), sw(fg)))
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h * og, params["out_proj"])
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


def mlstm_decode(cfg, params, x, state):
    B = x.shape[0]
    d = cfg.d_model
    q, k, v, ig, fg, og = _mlstm_bulk(cfg, params, x)
    carry = (state["C"], state["n"], state["m"])
    carry, h = _mlstm_step(carry, (q[:, 0], k[:, 0], v[:, 0],
                                   ig[:, 0], fg[:, 0]))
    h = h.reshape(B, 1, d).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h * og, params["out_proj"])
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


def mlstm_state_init(cfg, batch: int, dtype):
    nh = cfg.xlstm_heads
    hd = cfg.d_model // nh
    return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell with recurrent gates)
# ---------------------------------------------------------------------------

def slstm_spec(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    nh = cfg.xlstm_heads
    hd = d // nh
    return {
        "w": ParamSpec((d, 4, d), ("embed", None, None), scale=0.02),
        "r": ParamSpec((nh, 4, hd, hd), ("heads", None, None, None), scale=0.02),
        "bias": ParamSpec((4, d), (None, "embed"), init="zeros"),
        "out_proj": ParamSpec((d, d), (None, "embed")),
    }


def _slstm_step(cfg, params, carry, wx_t):
    """carry: (c,n,m,h) each (B,d) fp32; wx_t: (B,4,d)."""
    c, n, m, h = carry
    nh = cfg.xlstm_heads
    B, d = c.shape
    hd = d // nh
    hh = h.reshape(B, nh, hd)
    rh = jnp.einsum("bhk,hgkl->bghl", hh, params["r"].astype(jnp.float32))
    pre = wx_t.astype(jnp.float32) + rh.reshape(B, 4, d) \
        + params["bias"].astype(jnp.float32)
    z_t = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = jax.nn.log_sigmoid(pre[:, 2])
    o_t = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_prefill(cfg, params, x):
    B, S, d = x.shape
    wx = jnp.einsum("bsd,dge->bsge", x, params["w"])             # (B,S,4,d)
    carry = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3)) + (
        jnp.zeros((B, d), jnp.float32),)
    # fix m init
    carry = (carry[0], carry[1], jnp.full((B, d), -1e30, jnp.float32), carry[3])

    def step(carry, wx_t):
        new = _slstm_step(cfg, params, carry, wx_t)
        return new, new[3]

    carry, hs = jax.lax.scan(step, carry, jnp.swapaxes(wx, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h, params["out_proj"])
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}


def slstm_decode(cfg, params, x, state):
    B = x.shape[0]
    wx = jnp.einsum("bsd,dge->bsge", x, params["w"])
    carry = (state["c"], state["n"], state["m"], state["h"])
    new = _slstm_step(cfg, params, carry, wx[:, 0])
    out = jnp.einsum("bd,de->be", new[3].astype(x.dtype),
                     params["out_proj"])[:, None, :]
    return out, {"c": new[0], "n": new[1], "m": new[2], "h": new[3]}


def slstm_state_init(cfg, batch: int, dtype):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32)}
