"""Paged-KV model paths: prefill/decode over a unified page pool.

The serving engine's page pool (``repro.serving.kv_pool.PagePool``) holds one
physical K and V array shared by *all* of a node's local attention layers
(the paper's §5.1 "pool of pages unified for all local layers").  This module
is the model-side counterpart: it runs the layer stack with

  * full-attention GQA blocks reading/writing the shared pool through their
    per-layer block tables (decode goes through the Pallas paged_attention
    kernel), and
  * a dense fallback for everything else — MLA, SSM (mamba/xLSTM), windowed
    attention and encoder-decoder blocks keep their existing per-slot caches.

Paged layers are numbered prologue-first, then pattern positions in
repeat-major order; block tables follow the same layout so the super-block
``lax.scan`` can consume them as ``(repeats, paged_per_pattern, B, NP)``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import BlockSpec, ModelConfig
from .attention import gqa_decode_paged, gqa_prefill_paged
from .common import apply_norm
from .model import (_apply_block_decode, _cache_init_for_block, _embed,
                    _logits)
from .moe import ffn_apply, moe_apply


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------

def is_paged_block(cfg: ModelConfig, b: BlockSpec) -> bool:
    """True if this block's KV lives in the page pool (full-attention GQA).
    MLA / SSM / windowed / cross-attention blocks use the dense fallback."""
    return (b.kind == "attn" and b.attn == "full"
            and not cfg.mla_kv_lora_rank and not cfg.is_encoder_decoder)


def paged_layer_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(paged prologue blocks, paged blocks per pattern repeat)."""
    n_pro = sum(is_paged_block(cfg, b) for b in cfg.prologue)
    n_pp = sum(is_paged_block(cfg, b) for b in cfg.pattern)
    return n_pro, n_pp


def num_paged_layers(cfg: ModelConfig) -> int:
    n_pro, n_pp = paged_layer_counts(cfg)
    return n_pro + n_pp * cfg.repeats


def all_blocks_paged(cfg: ModelConfig) -> bool:
    """True if the whole stack is paged — enables chunked prefill (no dense
    caches at all); hybrid stacks prefill single-shot instead."""
    return all(is_paged_block(cfg, b) for b in cfg.blocks)


def init_caches_paged(cfg: ModelConfig, batch: int, max_len: int):
    """Dense-fallback caches: same pytree shape as ``init_caches`` but paged
    blocks hold an empty dict — their KV lives in the pool."""
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    caches: Dict[str, Any] = {}
    if cfg.prologue:
        caches["prologue"] = [
            {} if is_paged_block(cfg, b)
            else _cache_init_for_block(cfg, b, batch, max_len, dtype)
            for b in cfg.prologue]
    per_pos = {f"pos{i}": ({} if is_paged_block(cfg, b)
                           else _cache_init_for_block(cfg, b, batch, max_len,
                                                      dtype))
               for i, b in enumerate(cfg.pattern)}
    caches["super"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape), per_pos)
    return caches


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _mlp(cfg, p, h):
    if "moe" in p:
        hn = apply_norm(cfg, p["norm2"], h)
        out, _ = moe_apply(cfg, p["moe"], hn)
        return h + out
    if "ffn" in p:
        hn = apply_norm(cfg, p["norm2"], h)
        return h + ffn_apply(p["ffn"], hn)
    return h


def _block_decode_paged(cfg, p, h, kp, vp, ks, vs, table, cache_pos,
                        interpret):
    hn = apply_norm(cfg, p["norm1"], h)
    out, kp, vp, ks, vs = gqa_decode_paged(cfg, p["mix"], hn, kp, vp, table,
                                           cache_pos, k_scales=ks,
                                           v_scales=vs, interpret=interpret)
    return _mlp(cfg, p, h + out), kp, vp, ks, vs


def _block_prefill_paged(cfg, p, h, kp, vp, ks, vs, table, positions,
                         active_blocks=None):
    hn = apply_norm(cfg, p["norm1"], h)
    out, kp, vp, ks, vs = gqa_prefill_paged(cfg, p["mix"], hn, kp, vp, table,
                                            positions, k_scales=ks,
                                            v_scales=vs,
                                            active_blocks=active_blocks)
    return _mlp(cfg, p, h + out), kp, vp, ks, vs


# ---------------------------------------------------------------------------
# Model-level paged decode / chunked prefill
# ---------------------------------------------------------------------------

def decode_step_paged(cfg: ModelConfig, params, tokens, caches, cache_pos,
                      k_pages, v_pages, tables_pro, tables_super, *,
                      k_scales=None, v_scales=None, interpret: bool = False):
    """One autoregressive step over the paged pool.

    tokens/cache_pos: (B,); k/v_pages: (P,page,KH,D); tables_pro:
    (n_paged_prologue, B, NP); tables_super: (repeats, n_paged_pattern, B, NP);
    k/v_scales: (P, KH) f32 when the pool is int8, else None.
    Returns (logits (B,V), new dense-fallback caches, k_pages, v_pages,
    k_scales, v_scales).
    """
    positions = cache_pos[:, None]
    h = _embed(cfg, params, tokens[:, None], positions)

    new_caches: Dict[str, Any] = {}
    li = 0
    if cfg.prologue:
        new_caches["prologue"] = []
        for i, b in enumerate(cfg.prologue):
            if is_paged_block(cfg, b):
                h, k_pages, v_pages, k_scales, v_scales = _block_decode_paged(
                    cfg, params["prologue"][i], h, k_pages, v_pages,
                    k_scales, v_scales, tables_pro[li], cache_pos, interpret)
                new_caches["prologue"].append({})
                li += 1
            else:
                h, nc = _apply_block_decode(cfg, b, params["prologue"][i], h,
                                            caches["prologue"][i], cache_pos,
                                            None)
                new_caches["prologue"].append(nc)

    def superblock(carry, xs):
        h, kp, vp, ks, vs = carry
        layer_params, layer_cache, layer_tables = xs
        new_layer_cache = {}
        ti = 0
        for i, b in enumerate(cfg.pattern):
            if is_paged_block(cfg, b):
                h, kp, vp, ks, vs = _block_decode_paged(
                    cfg, layer_params[f"pos{i}"], h, kp, vp, ks, vs,
                    layer_tables[ti], cache_pos, interpret)
                new_layer_cache[f"pos{i}"] = {}
                ti += 1
            else:
                h, nc = _apply_block_decode(cfg, b, layer_params[f"pos{i}"],
                                            h, layer_cache[f"pos{i}"],
                                            cache_pos, None)
                new_layer_cache[f"pos{i}"] = nc
        return (h, kp, vp, ks, vs), new_layer_cache

    (h, k_pages, v_pages, k_scales, v_scales), new_super = jax.lax.scan(
        superblock, (h, k_pages, v_pages, k_scales, v_scales),
        (params["super"], caches["super"], tables_super))
    new_caches["super"] = new_super
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _logits(cfg, params, h)[:, 0]
    return logits, new_caches, k_pages, v_pages, k_scales, v_scales


def prefill_chunk_paged(cfg: ModelConfig, params, tokens, start_pos,
                        k_pages, v_pages, tables_pro, tables_super, *,
                        k_scales=None, v_scales=None, active_blocks=None):
    """Prefill one prompt chunk, appending its K/V to the pool.

    Only valid when ``all_blocks_paged(cfg)`` — every layer's history lives
    in the pool, so chunk N attends over chunks 0..N via the block tables and
    no dense caches are needed.  tokens: (B,C); start_pos: (B,) absolute
    position of tokens[:, 0].  ``active_blocks``: static per-layer gather cap
    (>= ceil((start+C)/page)); None gathers the whole NP budget.  Returns
    (last-token logits, k_pages, v_pages, k_scales, v_scales).
    """
    B, C = tokens.shape
    positions = start_pos[:, None] + jnp.arange(C)[None, :]
    h = _embed(cfg, params, tokens, positions)

    li = 0
    for i, b in enumerate(cfg.prologue):
        h, k_pages, v_pages, k_scales, v_scales = _block_prefill_paged(
            cfg, params["prologue"][i], h, k_pages, v_pages, k_scales,
            v_scales, tables_pro[li], positions, active_blocks)
        li += 1

    def superblock(carry, xs):
        h, kp, vp, ks, vs = carry
        layer_params, layer_tables = xs
        for i in range(len(cfg.pattern)):
            h, kp, vp, ks, vs = _block_prefill_paged(
                cfg, layer_params[f"pos{i}"], h, kp, vp, ks, vs,
                layer_tables[i], positions, active_blocks)
        return (h, kp, vp, ks, vs), None

    (h, k_pages, v_pages, k_scales, v_scales), _ = jax.lax.scan(
        superblock, (h, k_pages, v_pages, k_scales, v_scales),
        (params["super"], tables_super))
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _logits(cfg, params, h[:, -1:])[:, 0]
    return logits, k_pages, v_pages, k_scales, v_scales


# ---------------------------------------------------------------------------
# Dense-prefill absorption (hybrid stacks)
# ---------------------------------------------------------------------------

def absorb_dense_prefill(cfg: ModelConfig, caches, k_pages, v_pages,
                         table, slot: int, seq_len: int, page: int, *,
                         k_scales=None, v_scales=None):
    """Move a single-request dense prefill's GQA K/V into the page pool.

    Hybrid stacks (MLA/SSM/windowed blocks present) prefill single-shot with
    the dense ``prefill`` — correct at any prompt length — then scatter the
    full-attention layers' K/V into this slot's pages and drop those leaves
    (replaced by ``{}``), keeping only the fallback caches dense.

    caches: prefill output with batch 1; table: host (L, max_batch, NP) int32
    page-id array.  Int8 pools (``k_scales``/``v_scales`` given) quantize
    each destination page exactly once — no RMW drift on this path.
    Returns (caches', k_pages, v_pages, k_scales, v_scales).
    """
    import numpy as np

    n_pro, n_pp = paged_layer_counts(cfg)
    pos = np.arange(seq_len)
    blk, off = pos // page, jnp.asarray(pos % page)
    nblk = -(-seq_len // page)

    def scatter(layer_idx, k, v):
        nonlocal k_pages, v_pages, k_scales, v_scales
        if k_scales is not None:
            from ..kernels.paged_attention import quantize_kv_pages
            pids = jnp.asarray(table[layer_idx, slot, :nblk])
            pad = nblk * page - seq_len
            KH, D = k.shape[-2:]
            kb = jnp.pad(k.astype(jnp.float32), ((0, pad), (0, 0), (0, 0)))
            vb = jnp.pad(v.astype(jnp.float32), ((0, pad), (0, 0), (0, 0)))
            kq, ks = quantize_kv_pages(kb.reshape(nblk, page, KH, D))
            vq, vs = quantize_kv_pages(vb.reshape(nblk, page, KH, D))
            k_pages = k_pages.at[pids].set(kq)
            v_pages = v_pages.at[pids].set(vq)
            k_scales = k_scales.at[pids].set(ks)
            v_scales = v_scales.at[pids].set(vs)
            return
        pids = jnp.asarray(table[layer_idx, slot, blk])
        k_pages = k_pages.at[pids, off].set(k.astype(k_pages.dtype))
        v_pages = v_pages.at[pids, off].set(v.astype(v_pages.dtype))

    out: Dict[str, Any] = {}
    if cfg.prologue:
        out["prologue"] = []
        li = 0
        for i, b in enumerate(cfg.prologue):
            c = caches["prologue"][i]
            if is_paged_block(cfg, b):
                scatter(li, c["k"][0, :seq_len], c["v"][0, :seq_len])
                out["prologue"].append({})
                li += 1
            else:
                out["prologue"].append(c)
    out["super"] = {}
    ti = 0
    for i, b in enumerate(cfg.pattern):
        c = caches["super"][f"pos{i}"]
        if is_paged_block(cfg, b):
            for r in range(cfg.repeats):
                scatter(n_pro + r * n_pp + ti,
                        c["k"][r, 0, :seq_len], c["v"][r, 0, :seq_len])
            out["super"][f"pos{i}"] = {}
            ti += 1
        else:
            out["super"][f"pos{i}"] = c
    return out, k_pages, v_pages, k_scales, v_scales
