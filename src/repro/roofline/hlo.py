"""Post-SPMD HLO text analysis: collective bytes with while-loop trip counts.

XLA emits one module per SPMD program; computations are text blocks
``%name (...) -> ... {``.  Collectives inside a while body execute
trip-count times, so we build the computation call graph (while bodies,
conditionals, calls) and multiply.

Trip counts are recovered heuristically from the while condition: the
largest integer constant compared against in the condition computation
(standard XLA canonical loops compare the induction variable with the trip
count).  Fusion computations cannot contain collectives, so they are
ignored.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

COLLECTIVE_RE = re.compile(
    r"=\s+(?P<shape>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
               "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

# computation definitions start at column 0: ``%name (args) -> type {`` or
# ``ENTRY %name (...) -> ... {`` — args may contain nested parens, so only
# anchor on the name
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%([^\s(]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\([^)]*\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)"
    r"|while\([^)]*\)[^\n]*?body=%?([\w\.\-]+)[^\n]*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (brace matched from header lines)."""
    comps: Dict[str, str] = {}
    entry_name = None
    lines = hlo.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i]
        m = _COMP_HEADER.match(line)  # column 0 anchored
        if m and "{" in line:
            name = m.group(2)
            body = [line]
            depth = line.count("{") - line.count("}")
            i += 1
            while i < len(lines) and depth > 0:
                body.append(lines[i])
                depth += lines[i].count("{") - lines[i].count("}")
                i += 1
            comps[name] = "\n".join(body)
            if m.group(1):
                entry_name = name
        else:
            i += 1
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def shape_bytes(shape_text: str) -> int:
    nbytes = 0
    for dt, dims in SHAPE_RE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * DTYPE_BYTES[dt]
    return nbytes


def collective_bytes_of(comp_text: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    out: Dict[str, int] = defaultdict(int)
    count: Dict[str, int] = defaultdict(int)
    for m in COLLECTIVE_RE.finditer(comp_text):
        if m.group("suffix") == "-done":
            continue
        op = m.group("op")
        out[op] += shape_bytes(m.group("shape"))
        count[op] += 1
    return dict(out), dict(count)


def while_edges(comp_text: str) -> List[Tuple[str, str]]:
    """[(condition_comp, body_comp)] for each while op in this computation."""
    edges = []
    for m in _WHILE_RE.finditer(comp_text):
        if m.group(1):
            edges.append((m.group(1), m.group(2)))
        else:
            edges.append((m.group(4), m.group(3)))
    return edges


def trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def collective_totals(hlo: str) -> Tuple[Dict[str, int], Dict[str, int], Dict[str, int]]:
    """(bytes_by_kind, op_count_by_kind, multipliers) — per-device totals
    with while-loop trip multipliers applied."""
    comps = split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        b, c = collective_bytes_of(hlo)
        return b, c, {}

    multipliers: Dict[str, int] = defaultdict(int)

    def visit(name: str, mult: int, depth: int = 0):
        if depth > 16 or name not in comps:
            return
        multipliers[name] += mult
        text = comps[name]
        for cond, body in while_edges(text):
            n = trip_count(comps.get(cond, ""))
            visit(body, mult * max(n, 1), depth + 1)
        # call / conditional branches run once per invocation
        for m in re.finditer(r"(?:to_apply|branch_computations)=\{?%?([\w\.\-]+)",
                             text):
            callee = m.group(1)
            if callee in comps and callee != name:
                visit(callee, mult, depth + 1)

    entry_name = [k for k, v in comps.items()
                  if k != "__entry__" and v == entry]
    visit(entry_name[0] if entry_name else "__entry__", 1)
    if "__entry__" in multipliers and entry_name:
        multipliers.pop("__entry__", None)

    total_bytes: Dict[str, int] = defaultdict(int)
    total_count: Dict[str, int] = defaultdict(int)
    for name, mult in multipliers.items():
        if name == "__entry__":
            continue
        b, c = collective_bytes_of(comps[name])
        for k, v in b.items():
            total_bytes[k] += v * mult
        for k, v in c.items():
            total_count[k] += v * mult
    return dict(total_bytes), dict(total_count), dict(multipliers)


def top_collectives(hlo: str, k: int = 12) -> List[Dict]:
    """Largest collective contributors: (op, shape, per-op bytes, trip
    multiplier, total bytes, computation) sorted by total bytes."""
    comps = split_computations(hlo)
    entry = comps.get("__entry__")
    rows: List[Dict] = []
    multipliers: Dict[str, int] = defaultdict(int)

    def visit(name: str, mult: int, depth: int = 0):
        if depth > 16 or name not in comps:
            return
        multipliers[name] += mult
        text = comps[name]
        for cond, body in while_edges(text):
            n = trip_count(comps.get(cond, ""))
            visit(body, mult * max(n, 1), depth + 1)
        for m in re.finditer(r"(?:to_apply|branch_computations)=\{?%?([\w\.\-]+)",
                             text):
            callee = m.group(1)
            if callee in comps and callee != name:
                visit(callee, mult, depth + 1)

    if entry is not None:
        entry_name = [kk for kk, v in comps.items()
                      if kk != "__entry__" and v == entry]
        visit(entry_name[0] if entry_name else "__entry__", 1)
    for name, mult in multipliers.items():
        if name == "__entry__":
            continue
        for m in COLLECTIVE_RE.finditer(comps[name]):
            if m.group("suffix") == "-done":
                continue
            b = shape_bytes(m.group("shape"))
            rows.append({"op": m.group("op"),
                         "shape": m.group("shape").strip()[:70],
                         "bytes": b, "mult": mult, "total": b * mult,
                         "computation": name[:40]})
    rows.sort(key=lambda r: -r["total"])
    return rows[:k]
