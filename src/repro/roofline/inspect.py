import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb profiler: recompile one cell, print the top collectives.

  PYTHONPATH=src python -m repro.roofline.inspect --arch starcoder2_7b \
      --shape prefill_32k [--mesh single] [--dump PATH] [--extra k=v,...]
"""
import argparse
import json

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.hlo import collective_totals, top_collectives


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--dump", default="")
    ap.add_argument("--extra", default="", help="k=v,... passed to build_cell")
    args = ap.parse_args()

    extra = {}
    for kv in args.extra.split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            extra[k] = (v if not v.replace(".", "").isdigit()
                        else (int(v) if v.isdigit() else float(v)))
            if v in ("true", "false"):
                extra[k] = v == "true"
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    cell = build_cell(args.arch, args.shape, mesh, extra=extra or None)
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums) \
            .lower(*cell.args).compile()
    hlo = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(hlo)
    total, count, _ = collective_totals(hlo)
    print(f"cell: {cell.description}")
    print("totals (per device):",
          {k: f"{v / 1e9:.2f}GB" for k, v in total.items()})
    print(f"\ntop collectives:")
    for r in top_collectives(hlo, 14):
        print(f"  {r['total'] / 1e9:9.3f}GB  x{r['mult']:<6d} {r['op']:20s} "
              f"{r['shape'][:58]:58s} in {r['computation']}")


if __name__ == "__main__":
    main()
