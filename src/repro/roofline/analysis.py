"""Roofline analysis for dry-run cells.

Three terms per (arch x shape x mesh), in seconds per executed step:

  compute    = FLOPs_global    / (chips * 197e12)      [bf16 peak/chip]
  memory     = HBM_bytes_global/ (chips * 819e9)
  collective = coll_bytes_local/  50e9                 [per-link ICI]

Why analytic FLOPs/bytes instead of ``compiled.cost_analysis()``: XLA's HLO
cost analysis counts a while-loop body ONCE — with layers under lax.scan and
token/chunk loops inside blocks, the reported flops undercount by 2-3 orders
of magnitude on this CPU backend (verified: smollm train_4k reports 1.2e13
vs 8.9e15 actual per device).  We therefore compute executed FLOPs/bytes
from the model structure (counting mask-wasted work the baseline really
executes), and parse collectives out of the post-SPMD HLO *with while-loop
trip-count multipliers* (repro.roofline.hlo).  ``cost_analysis`` numbers are
still recorded raw in the dry-run JSON for reference.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from ..configs.base import BlockSpec, ModelConfig

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link


def _dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


@dataclasses.dataclass
class FlopsOptions:
    # does chunked attention skip fully-masked kv chunks? (baseline: no)
    skip_masked_chunks: bool = False
    # training remat policy recomputes the forward in the backward pass
    remat_refwd: bool = True
    moe_capacity_factor: float = 1.25


def attn_flops_per_token(cfg: ModelConfig, b: BlockSpec, s_kv: float,
                         decode: bool) -> float:
    """Projections + score/value matmul flops for ONE token through one
    attention block, attending to ``s_kv`` kv positions (already adjusted
    for causal/window by the caller)."""
    d = cfg.d_model
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.mla_kv_lora_rank:
        r_q = cfg.mla_q_lora_rank or d
        r_kv = cfg.mla_kv_lora_rank
        nope, rope, vd = (cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim,
                          cfg.mla_v_dim)
        proj = 2 * (d * r_q + r_q * H * (nope + rope) + d * (r_kv + rope))
        if decode:
            # absorbed: fold kv_up into q/out; attention runs in rank space
            proj += 2 * (H * nope * r_kv + H * r_kv * vd)
            attn = 2 * H * s_kv * (r_kv + rope) + 2 * H * s_kv * r_kv
        else:
            proj += 2 * r_kv * H * (nope + vd)
            attn = 2 * H * s_kv * (nope + rope) + 2 * H * s_kv * vd
        proj += 2 * H * vd * d
        return proj + attn
    proj = 2 * d * H * hd + 2 * 2 * d * KH * hd + 2 * H * hd * d
    attn = 2 * H * hd * s_kv * 2          # qk^T and p@v
    return proj + attn


def block_flops_per_token(cfg: ModelConfig, b: BlockSpec, seq: int,
                          decode: bool, opts: FlopsOptions) -> float:
    d = cfg.d_model
    f = 0.0
    if b.kind == "attn":
        window = b.window if b.attn in ("swa", "local") else 0
        if decode:
            s_kv = min(window, seq) if window else seq
        else:
            # executed kv length per query token in chunked prefill:
            # baseline computes ALL chunks (mask waste); skip-chunks halves
            # causal and clamps windowed
            if opts.skip_masked_chunks:
                s_kv = min(window, seq / 2) if window else seq / 2
            else:
                s_kv = seq
        f += attn_flops_per_token(cfg, b, s_kv, decode)
    elif b.kind == "mamba":
        di = cfg.ssm_expand * d
        N = cfg.ssm_state_dim
        r = _dt_rank(cfg)
        f += 2 * d * 2 * di + 2 * cfg.ssm_conv_width * di
        f += 2 * di * (r + 2 * N) + 2 * r * di
        f += 10 * di * N                  # recurrence update + readout
        f += 2 * di * d
    elif b.kind == "mlstm":
        nh = cfg.xlstm_heads
        hd = d // nh
        f += 3 * 2 * d * d + 2 * 2 * d * nh + 2 * d * d   # qkv + gates + og
        f += 6 * nh * hd * hd            # C update + C@q
        f += 2 * d * d                   # out_proj
    elif b.kind == "slstm":
        nh = cfg.xlstm_heads
        hd = d // nh
        f += 2 * d * 4 * d + 2 * 4 * nh * hd * hd + 2 * d * d
    # FFN
    mats = 3 if cfg.mlp_kind == "gated" else 2
    if b.moe:
        ff = cfg.moe_d_ff or cfg.d_ff
        executed_k = cfg.moe_top_k * opts.moe_capacity_factor
        f += 2 * d * cfg.moe_num_experts                    # router
        f += executed_k * mats * 2 * d * ff
        f += cfg.moe_num_shared * mats * 2 * d * ff
    elif cfg.d_ff > 0:
        f += mats * 2 * d * cfg.d_ff
    return f


def forward_flops(cfg: ModelConfig, batch: int, seq: int, decode: bool,
                  opts: Optional[FlopsOptions] = None) -> float:
    """Global executed FLOPs for one forward pass (decode: one step)."""
    opts = opts or FlopsOptions()
    tokens = batch * (1 if decode else seq)
    per_token = sum(block_flops_per_token(cfg, b, seq, decode, opts)
                    for b in cfg.blocks)
    per_token += 2 * cfg.d_model * cfg.vocab_size       # logits
    total = tokens * per_token
    if cfg.is_encoder_decoder and not decode:
        enc_tok = batch * cfg.max_source_positions
        enc_block = BlockSpec(kind="attn", attn="full")
        enc = enc_tok * (attn_flops_per_token(
            cfg, enc_block, cfg.max_source_positions, False)
            + (3 if cfg.mlp_kind == "gated" else 2) * 2 * cfg.d_model * cfg.d_ff)
        total += enc * cfg.encoder_layers
        # decoder cross-attention
        cross = tokens * len(cfg.blocks) * (
            2 * 4 * cfg.d_model * cfg.num_heads * cfg.resolved_head_dim
            + 2 * cfg.num_heads * cfg.resolved_head_dim
            * cfg.max_source_positions * 2)
        total += cross
    return total


def cell_flops(cfg: ModelConfig, kind: str, batch: int, seq: int,
               opts: Optional[FlopsOptions] = None) -> Dict[str, float]:
    """Executed + model ("useful") FLOPs for one step of this cell."""
    opts = opts or FlopsOptions()
    # 6ND convention: N excludes embedding/unembedding parameters
    n_embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_eff = max(cfg.active_param_count() - n_embed, 1)
    if kind == "train":
        fwd = forward_flops(cfg, batch, seq, decode=False, opts=opts)
        mult = 3.0 + (1.0 if opts.remat_refwd else 0.0)
        executed = fwd * mult
        model = 6.0 * n_eff * batch * seq
    elif kind == "prefill":
        executed = forward_flops(cfg, batch, seq, decode=False, opts=opts)
        model = 2.0 * n_eff * batch * seq
    else:  # decode
        executed = forward_flops(cfg, batch, seq, decode=True, opts=opts)
        model = 2.0 * n_eff * batch
    return {"executed": executed, "model": model,
            "useful_frac": model / max(executed, 1.0)}


# ---------------------------------------------------------------------------
# HBM traffic (analytic, global bytes per step)
# ---------------------------------------------------------------------------

def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    total = 0.0
    dt = 2  # bf16
    for b in cfg.blocks:
        if b.kind == "attn":
            if cfg.mla_kv_lora_rank:
                per_tok = (cfg.mla_kv_lora_rank + cfg.mla_qk_rope_dim) * dt
                length = seq
            else:
                window = b.window if b.attn in ("swa", "local") else 0
                length = min(window, seq) if window else seq
                per_tok = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * dt
            total += batch * length * per_tok
        elif b.kind == "mamba":
            di = cfg.ssm_expand * cfg.d_model
            total += batch * (di * cfg.ssm_state_dim * 4
                              + (cfg.ssm_conv_width - 1) * di * dt)
        elif b.kind == "mlstm":
            nh = cfg.xlstm_heads
            hd = cfg.d_model // nh
            total += batch * nh * (hd * hd + hd + 1) * 4
        elif b.kind == "slstm":
            total += batch * 4 * cfg.d_model * 4
    return total


def cell_hbm_bytes(cfg: ModelConfig, kind: str, batch: int, seq: int
                   ) -> Dict[str, float]:
    dt = 2
    params = cfg.param_count() * dt
    active = cfg.active_param_count() * dt
    tokens = batch * seq
    act_unit = cfg.d_model * dt * cfg.num_layers
    if kind == "train":
        # params: fwd read + remat re-read + bwd read + grad write +
        # optimizer read/write (fp32 factored state ~ small) ≈ 5x
        param_io = 5.0 * params
        act_io = 16.0 * tokens * act_unit
        kv_io = 0.0
    elif kind == "prefill":
        param_io = 1.0 * params
        act_io = 8.0 * tokens * act_unit
        kv_io = kv_cache_bytes(cfg, batch, seq)          # cache write
    else:  # decode: one step reads active params + whole KV, writes 1 token
        param_io = 1.0 * active
        act_io = 8.0 * batch * act_unit
        kv_io = kv_cache_bytes(cfg, batch, seq)
    return {"params": param_io, "activations": act_io, "kv": kv_io,
            "total": param_io + act_io + kv_io}


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------

def roofline_terms(cfg: ModelConfig, kind: str, batch: int, seq: int,
                   chips: int, collective_local_bytes: float,
                   opts: Optional[FlopsOptions] = None) -> Dict[str, float]:
    fl = cell_flops(cfg, kind, batch, seq, opts)
    hbm = cell_hbm_bytes(cfg, kind, batch, seq)
    compute_s = fl["executed"] / (chips * PEAK_FLOPS)
    memory_s = hbm["total"] / (chips * HBM_BW)
    collective_s = collective_local_bytes / LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_step_s": total,
        "flops_executed": fl["executed"],
        "flops_model": fl["model"],
        "useful_frac": fl["useful_frac"],
        "hbm_bytes": hbm["total"],
        "hbm_breakdown": hbm,
        "roofline_frac": (fl["model"] / (chips * PEAK_FLOPS)) / total
        if total > 0 else 0.0,
    }
