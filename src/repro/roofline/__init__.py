"""Roofline analysis: analytic FLOPs/bytes + HLO collective accounting."""
from .analysis import (FlopsOptions, HBM_BW, LINK_BW, PEAK_FLOPS, cell_flops,
                       cell_hbm_bytes, forward_flops, kv_cache_bytes,
                       roofline_terms)
from .hlo import collective_totals, shape_bytes, split_computations
