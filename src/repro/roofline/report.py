"""Build the EXPERIMENTS.md roofline/dry-run tables from dry-run JSONs.

Usage: PYTHONPATH=src python -m repro.roofline.report results/dryrun
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load_records(dirpath: str, mesh: str = "single",
                 tag: str = "") -> List[Dict]:
    out = []
    for name in sorted(os.listdir(dirpath)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(dirpath, name)) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh and rec.get("tag", "") == tag:
            out.append(rec)
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def roofline_table(records: List[Dict]) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful FLOPs | roofline frac | bottleneck note |",
            "|---|---|---|---|---|---|---|---|---|"]
    recs = sorted([r for r in records if r["status"] == "ok"],
                  key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        rl = r.get("roofline", {})
        if not rl:
            continue
        note = bottleneck_note(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"**{rl['dominant']}** | {rl['useful_frac'] * 100:.0f}% | "
            f"{rl['roofline_frac'] * 100:.0f}% | {note} |")
    skipped = [r for r in records if r["status"] == "skipped"]
    for r in sorted(skipped, key=lambda r: r["arch"]):
        rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — "
                    f"| — | {r['reason'][:60]} |")
    return "\n".join(rows)


def bottleneck_note(r: Dict) -> str:
    rl = r["roofline"]
    dom = rl["dominant"]
    if dom == "collective":
        kinds = r.get("collective_bytes", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        return f"{top} dominates ({kinds.get(top, 0) / 1e9:.1f}GB/dev); " \
               f"overlap or reshard to cut it"
    if dom == "memory":
        br = rl.get("hbm_breakdown", {})
        top = max((k for k in ("params", "activations", "kv")),
                  key=lambda k: br.get(k, 0))
        return f"HBM bound by {top}; raise arithmetic intensity (batch/fuse)"
    if rl["useful_frac"] < 0.6:
        return "compute bound with mask/remat waste; cut wasted FLOPs"
    return "compute bound near peak; increase per-chip utilization"


def dryrun_table(records: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile | params/dev | "
            "cache/dev | HLO coll (count) |",
            "|---|---|---|---|---|---|---|---|"]
    recs = sorted(records, key=lambda r: (r["arch"],
                                          SHAPE_ORDER.index(r["shape"]),
                                          r["mesh"]))
    for r in recs:
        if r["status"] == "ok":
            pb = r.get("params_bytes_per_device", 0) / 1e9
            cb = r.get("cache_bytes_per_device", 0) / 1e9
            cc = sum(r.get("collective_count", {}).values())
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('compile_s', 0):.0f}s | {pb:.2f}GB | "
                f"{cb:.2f}GB | {cc} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['status']} | — | — | — | "
                        f"{r.get('reason', r.get('error', ''))[:50]} |")
    return "\n".join(rows)


def main() -> None:
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    singles = load_records(dirpath, "single")
    multis = load_records(dirpath, "multi")
    print("## Roofline (single-pod 16x16, per executed step)\n")
    print(roofline_table(singles))
    print("\n## Dry-run summary (single-pod)\n")
    print(dryrun_table(singles))
    if multis:
        print("\n## Dry-run summary (multi-pod 2x16x16)\n")
        print(dryrun_table(multis))


if __name__ == "__main__":
    main()
