import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Helix-integration dry-run: MILP placement -> unequal pipeline stages ->
shard_map pipeline loss lowered on the production mesh.

This is the paper's technique driving the TPU distribution layer end to
end: a heterogeneous cluster of TPU slices is planned with the max-flow
MILP; the resulting per-node layer ranges become the (unequal) stage sizes
of a ("stage","data") pipeline; the GPipe-style loss lowers + compiles at
512 chips.

  PYTHONPATH=src python -m repro.launch.pipeline_dryrun \
      [--arch starcoder2_7b] [--stages 16]
"""
import argparse
import json
import math
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import MILPOptions, ModelProfile, solve_placement
from repro.core.cluster import (COORDINATOR, DEVICE_PROFILES, ClusterSpec,
                                NodeSpec, _full_mesh_links)
from repro.dist.pipeline import (PipelineConfig, make_pipeline_loss,
                                 pipeline_param_specs,
                                 stage_units_from_placement)
from repro.models.common import abstract_shapes
from repro.roofline.hlo import collective_totals


def make_tpu_stage_cluster(num_nodes: int, model: ModelProfile,
                           headroom: float = 1.25,
                           param_frac: float = 0.5) -> ClusterSpec:
    """Heterogeneous TPU-slice cluster: alternating 4-chip and 1-chip v5e
    slices (incremental fleet), one Helix node per slice; VRAM forces a
    genuine pipeline (no slice can hold the whole model).

    Slice HBM is derated so the whole fleet holds ``headroom`` x the model:
    4-chip slices get a 2:1 layer budget over 1-chip ones, which is what
    makes the MILP hand out *unequal* stage sizes."""
    import dataclasses as dc
    kinds = ["TPUv5e-4", "TPUv5e"]
    weights = [2 if i % 2 == 0 else 1 for i in range(num_nodes)]
    total_w = sum(weights)
    nodes, regions = {}, {COORDINATOR: "r0"}
    for i in range(num_nodes):
        name = f"slice-{i}"
        cap_layers = max(1, math.ceil(
            model.num_layers * headroom * weights[i] / total_w))
        cap_layers = min(cap_layers, model.num_layers - 1) \
            if num_nodes > 1 else model.num_layers
        dev = dc.replace(
            DEVICE_PROFILES[kinds[i % 2]],
            vram_bytes=cap_layers * model.layer_param_bytes / param_frac)
        nodes[name] = NodeSpec(name, dev, region="r0")
        regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions, 6.25e9, 1e-4,
                             6.25e9, 1e-4)
    return ClusterSpec(nodes=nodes, links=links)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chameleon_34b")
    ap.add_argument("--stages", type=int, default=16)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--out", default="results/dryrun/pipeline.json")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    profile = ModelProfile.from_dims(
        cfg.name, cfg.repeats, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    cluster = make_tpu_stage_cluster(args.stages, profile)

    print(f"planning {args.stages}-slice heterogeneous chain for {cfg.name}")
    result = solve_placement(cluster, profile, MILPOptions(
        time_limit_s=15.0, lns_rounds=0, fgls_rounds=30))
    order = sorted(result.placement.assignment,
                   key=lambda n: result.placement.assignment[n].start)
    units = stage_units_from_placement(result.placement, cfg, order)
    print(f"stage units from MILP placement (4-chip slices get more): "
          f"{units}")
    # placements may use fewer nodes than requested stages; zero-unit
    # stages are identity pass-throughs in the pipeline
    units = units + [0] * (args.stages - len(units))

    if 512 % args.stages:
        raise SystemExit(f"--stages {args.stages} must divide the 512-chip "
                         f"mesh")
    data_dim = 512 // args.stages
    if args.batch % data_dim:
        raise SystemExit(f"--batch {args.batch} must be divisible by the "
                         f"data-axis size ({data_dim})")
    microbatches = math.gcd(args.microbatches, args.batch // data_dim)
    if microbatches != args.microbatches:
        print(f"clamping microbatches {args.microbatches} -> {microbatches} "
              f"(per-data-shard batch is {args.batch // data_dim})")
    pipe = PipelineConfig(num_stages=args.stages, stage_units=tuple(units),
                          num_microbatches=microbatches)
    mesh = jax.make_mesh((args.stages, data_dim), ("stage", "data"))
    specs = pipeline_param_specs(cfg, pipe)
    params_abs = abstract_shapes(specs, cfg.param_dtype)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
    }
    loss = make_pipeline_loss(cfg, pipe, mesh)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(loss).lower(params_abs, batch_abs)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    coll, count, _ = collective_totals(hlo)
    rec = {
        "arch": args.arch, "stages": args.stages,
        "stage_units": units,
        "mesh": {"stage": args.stages, "data": 512 // args.stages},
        "placement_throughput": result.actual_throughput,
        "compile_s": round(time.time() - t0, 1),
        "collective_bytes": coll, "collective_count": count,
        "status": "ok",
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"compiled in {rec['compile_s']}s; collectives/dev: "
          f"{ {k: f'{v/1e9:.2f}GB' for k, v in coll.items()} }")
    print("pipeline dry-run OK")


if __name__ == "__main__":
    main()
