"""Production meshes (functions, not module constants — importing this
module must never touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(shape, axes)
