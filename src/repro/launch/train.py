"""Distributed training driver.

On a real TPU pod this runs the sharded train step for an assigned arch with
checkpoint/restart; on CPU it runs the same code path on a small forced-host
mesh for validation:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
      --smoke --mesh 2,4 --steps 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import optimizer_for
from repro.models import init
from repro.training import (AsyncCheckpointer, DataConfig, TrainConfig,
                            init_train_state, latest_step, make_batch,
                            make_sharded_train_step, restore)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--mesh", default="",
                    help="comma dims, e.g. 2,4 -> (data, model)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
    else:
        dims = (jax.device_count(), 1)
    axes = ("data", "model")[:len(dims)] if len(dims) == 2 \
        else ("pod", "data", "model")
    mesh = jax.make_mesh(dims, axes)
    print(f"mesh {dict(zip(axes, dims))}; model {cfg.name} "
          f"({cfg.param_count() / 1e6:.1f}M params)")

    tc = TrainConfig(optimizer=optimizer_for(cfg), remat="full")
    step_fn, params_sh, opt_sh = make_sharded_train_step(cfg, tc, mesh)

    with mesh:
        params = jax.jit(lambda k: init(cfg, k),
                         out_shardings=params_sh)(jax.random.key(0))
        opt_state = jax.jit(lambda p: init_train_state(cfg, tc, p),
                            out_shardings=opt_sh)(params)

        dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=args.batch,
                        seq_len=args.seq)
        start = 0
        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir):
            state, step, meta = restore(args.ckpt_dir, None,
                                        {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = meta["data_step"]
            print(f"resumed at data step {start}")

        t0 = time.time()
        for s in range(start, args.steps):
            batch = make_batch(dc, s)
            params, opt_state, m = step_fn(params, opt_state, batch)
            print(f"step {s}: loss {float(m['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
            if ckpt and (s + 1) % 20 == 0:
                ckpt.save_async(s + 1, {"params": params, "opt": opt_state},
                                metadata={"data_step": s + 1})
        if ckpt:
            ckpt.wait()


if __name__ == "__main__":
    main()
