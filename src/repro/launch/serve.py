"""Distributed serving driver: sharded prefill + decode for an assigned arch.

CPU validation:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --mesh 2,4 --batch 4 --prompt 16 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist.sharding import SERVE_RULES, tree_shardings
from repro.launch.steps import abstract_params
from repro.models import decode_step, init, init_caches, prefill
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dims = tuple(int(x) for x in args.mesh.split(",")) if args.mesh \
        else (jax.device_count(), 1)
    axes = ("data", "model")[:len(dims)] if len(dims) == 2 \
        else ("pod", "data", "model")
    mesh = jax.make_mesh(dims, axes)
    print(f"mesh {dict(zip(axes, dims))}; serving {cfg.name}")

    params_abs, params_axes = abstract_params(cfg)
    params_sh = tree_shardings(params_abs, params_axes, SERVE_RULES, mesh)

    with mesh:
        params = jax.jit(lambda k: init(cfg, k),
                         out_shardings=params_sh)(jax.random.key(0))
        rng = np.random.RandomState(0)
        tokens = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                         size=(args.batch, args.prompt)),
                             jnp.int32)
        kw = {}
        if cfg.is_encoder_decoder:
            kw["encoder_frames"] = jnp.asarray(
                rng.randn(args.batch, 16, cfg.d_model), jnp.bfloat16)

        t0 = time.time()
        logits, caches = jax.jit(
            lambda p, t: prefill(cfg, p, t, max_len=args.max_len, **kw)
        )(params, tokens)
        print(f"prefill: {time.time() - t0:.2f}s")
        dec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))
        out = [np.asarray(jnp.argmax(logits, -1))]
        pos = jnp.full((args.batch,), args.prompt, jnp.int32)
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            logits, caches = dec(params, jnp.asarray(out[-1]), caches,
                                 pos + i)
            out.append(np.asarray(jnp.argmax(logits, -1)))
        dt = time.time() - t0
        print(f"decode: {args.new_tokens - 1} steps in {dt:.2f}s "
              f"({(args.new_tokens - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
        print("sampled ids:", np.stack(out, 1)[:2].tolist())


if __name__ == "__main__":
    main()
