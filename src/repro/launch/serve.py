"""Distributed serving driver: sharded prefill + decode for an assigned arch.

CPU validation:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --mesh 2,4 --batch 4 --prompt 16 --new-tokens 8

Paged-KV engine (per-node worker; pool sized from node VRAM like the
simulator sizes KV capacity; Pallas kernel interpreted off-TPU):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --paged --vram-gb 16 --batch 4 --prompt 40 --new-tokens 8

Multi-node cluster serving (MILP placement -> IWRR pipelines -> stage
engines under the ClusterRuntime; one process plays every node):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --cluster A100,L4,T4 --stages 2 --batch 4 --prompt 10 --new-tokens 8

Multi-process cluster serving (one StageWorker process per node behind the
SocketTransport; add --connect HOST:PORT to use externally started
``python -m repro.launch.worker`` processes, e.g. on other hosts):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --cluster A100,L4 --stages 2 --transport socket --new-tokens 8

Online front door (OpenAI-compatible HTTP API + SSE streaming over the
cluster runtime; drive it with examples/openloop_client.py):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --cluster A100,L4 --stages 2 --serve 127.0.0.1:8000
"""
from __future__ import annotations

import argparse
import json
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (MILPOptions, ModelProfile, make_serving_cluster,
                        plan)
from repro.dist.sharding import SERVE_RULES, tree_shardings
from repro.launch.steps import abstract_params
from repro.models import decode_step, init, init_caches, prefill
from repro.models import model as M
from repro.serving import (ClusterRuntime, EngineConfig, PagedEngine, Request,
                           full_rectangle_pages, pages_for_vram)


def run_paged(cfg, args) -> None:
    """Single-node paged-KV serving: VRAM-derived pool, chunked prefill for
    prompts past the bucket, paged_attention decode."""
    ec = EngineConfig(max_batch=args.batch, max_len=args.max_len,
                      prompt_len=min(16, args.max_len))
    kv_dtype = args.kv_dtype if args.kv_dtype != "param" else None
    vram_pages = pages_for_vram(cfg, args.vram_gb * 1e9,
                                page_size=args.page_size, kv_dtype=kv_dtype)
    rect = full_rectangle_pages(cfg, max_batch=ec.max_batch,
                                max_len=ec.max_len, page_size=args.page_size)
    num_pages = min(vram_pages, rect) if args.vram_gb > 0 else rect
    print(f"pool: {num_pages} pages x {args.page_size} tokens, "
          f"kv_dtype={args.kv_dtype} "
          f"(VRAM budget {vram_pages}, full rectangle {rect})")
    params = init(cfg, jax.random.key(0))
    eng = PagedEngine(cfg, params, ec, num_pages=num_pages,
                      page_size=args.page_size, kv_dtype=kv_dtype)
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(args.prompt,)),
                    max_new_tokens=args.new_tokens)
            for i in range(args.batch)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_iters=10000)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    assert all(r.done for r in reqs)
    assert eng.pool.used == 0, "pages leaked"
    print(f"paged: {len(reqs)} reqs, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s); pool clean")
    print("sampled ids:", [r.output for r in reqs[:2]])


def run_cluster(cfg, args) -> None:
    """Multi-node serving: MILP placement over a (VRAM-derated) cluster, one
    stage engine per node, requests walking IWRR pipelines through the
    ClusterRuntime."""
    kv_dtype = args.kv_dtype if args.kv_dtype != "param" else None
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim,
        kv_dtype=args.kv_dtype, kv_page_size=args.page_size)
    cluster = make_serving_cluster(profile, devs=args.cluster.split(","),
                                   force_stages=args.stages)
    p = plan(cluster, profile, MILPOptions(time_limit_s=10.0, lns_rounds=0,
                                           fgls_rounds=20))
    for node, rng_ in sorted(p.placement.assignment.items()):
        print(f"  {node}: layers [{rng_.start}, {rng_.end})")
    params = init(cfg, jax.random.key(0))
    ec = EngineConfig(max_batch=args.batch, max_len=args.max_len,
                      prompt_len=min(16, args.max_len))
    spec_kw = {}
    if args.draft:
        # coordinator-side draft model for speculative decoding: any arch
        # sharing the target's vocab works; quality only changes speed
        dcfg = (get_smoke_config(args.draft) if args.smoke
                else get_config(args.draft))
        print(f"draft: {dcfg.name} ({dcfg.num_layers}L d={dcfg.d_model}), "
              f"spec_tokens={args.spec_tokens}")
        spec_kw = dict(draft_cfg=dcfg,
                       draft_params=init(dcfg, jax.random.key(0)),
                       spec_tokens=args.spec_tokens)
    if args.transport == "socket":
        rt = ClusterRuntime.spawn_workers(
            cfg, params, p, ec, paged=args.paged or not args.dense,
            page_size=args.page_size, kv_dtype=kv_dtype,
            max_inflight=args.max_inflight,
            connect=args.connect or None, stall_timeout_s=120.0,
            direct_links=args.direct_links, **spec_kw)
    else:
        rt = ClusterRuntime(cfg, params, p, ec,
                            paged=args.paged or not args.dense,
                            page_size=args.page_size, kv_dtype=kv_dtype,
                            max_inflight=args.max_inflight,
                            # the front door needs wall-clock arrivals even
                            # over the in-process transport
                            realtime=True if args.serve else None,
                            **spec_kw)
    if args.serve:
        run_frontdoor(cfg, rt, args, plan_obj=p)
        return
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(args.prompt,)),
                    max_new_tokens=args.new_tokens)
            for i in range(args.batch)]
    t0 = time.time()
    for r in reqs:
        rt.submit(r)
    rt.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        print(f"req{r.request_id} -> "
              + " -> ".join(s.node for s in rt.served[r.request_id].stages))
    print(f"cluster: {len(reqs)} reqs, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)")
    if args.draft:
        print(f"  {rt._spec_note()}")
    print("sampled ids:", [r.output for r in reqs[:2]])
    rt.shutdown()                      # reap worker processes (socket runs)


def run_frontdoor(cfg, rt, args, plan_obj=None) -> None:
    """Serve the runtime behind the OpenAI-compatible HTTP front door
    until SIGINT/SIGTERM, then drain gracefully and print the
    server-side TTFT/TPOT/SLO summary."""
    import dataclasses as _dc

    from repro.serving.frontend import Frontend

    host, _, port = args.serve.rpartition(":")
    fe = Frontend(rt, max_pending=args.max_pending,
                  slo_ttft_s=args.slo_ttft_ms / 1e3
                  if args.slo_ttft_ms > 0 else None,
                  slo_tpot_s=args.slo_tpot_ms / 1e3
                  if args.slo_tpot_ms > 0 else None)
    scaler = None
    if getattr(args, "autoscale", False) and plan_obj is not None:
        from repro.core.cluster import COORDINATOR
        from repro.serving.autoscaler import Autoscaler

        catalog = None
        if args.autoscale_node_rate > 0:
            # cap every device's modeled token rate so the mix planner
            # sees a small, known per-node capacity — smoke runs on tiny
            # CPU models would otherwise look infinitely fast on paper and
            # never scale
            catalog = {n.device.name:
                       _dc.replace(n.device,
                                   max_tokens_per_s=args.autoscale_node_rate)
                       for name, n in rt.cluster.nodes.items()
                       if name != COORDINATOR}
        scaler = Autoscaler(rt, plan_obj, frontend=fe, catalog=catalog,
                            patience=args.autoscale_patience,
                            window_s=args.autoscale_window_s)
        scaler.start(args.autoscale_interval_s)
        print(f"autoscaler: interval={args.autoscale_interval_s}s "
              f"patience={args.autoscale_patience} "
              f"window={args.autoscale_window_s}s "
              f"catalog={sorted(scaler.catalog)}", flush=True)
    bhost, bport = fe.serve(host or "127.0.0.1", int(port))
    print(f"serving {cfg.name} on http://{bhost}:{bport} "
          f"(POST /v1/completions, GET /healthz; SIGINT drains)",
          flush=True)
    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())
    while not stop.is_set():
        stop.wait(0.2)
    print("draining ...", flush=True)
    if scaler is not None:
        scaler.stop()
    fe.shutdown(drain=True)
    if scaler is not None:
        print("autoscale events: " + json.dumps(
            [_dc.asdict(e) for e in scaler.events], default=float),
            flush=True)
    print("served summary: "
          + json.dumps(fe.summary(), default=float), flush=True)
    rt.shutdown()
    if fe.loop_error is not None:
        raise SystemExit(f"runtime loop died: {fe.loop_error!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged-KV engine (single node)")
    ap.add_argument("--dense", action="store_true",
                    help="with --cluster: dense stage engines, not paged")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", choices=["param", "int8"], default="param",
                    help="KV page storage: 'param' keeps the model dtype, "
                         "'int8' quantizes pages (per-page per-head absmax "
                         "scales) for ~2x pool capacity at fixed VRAM")
    ap.add_argument("--vram-gb", type=float, default=16.0,
                    help="node VRAM for pool sizing (0 = full rectangle)")
    ap.add_argument("--cluster", default="",
                    help="comma-separated device types: serve a multi-node "
                         "cluster through the ClusterRuntime")
    ap.add_argument("--stages", type=int, default=0,
                    help="with --cluster: derate VRAM to force >= N stages")
    ap.add_argument("--max-inflight", type=int, default=1,
                    help="with --cluster: per-request in-flight decode "
                         "window (pipelined decode at >= 2)")
    ap.add_argument("--transport", choices=["inproc", "socket"],
                    default="inproc",
                    help="with --cluster: socket runs one StageWorker "
                         "process per node behind the SocketTransport")
    ap.add_argument("--connect", default="",
                    help="with --transport socket: listen on HOST:PORT and "
                         "wait for externally started workers (python -m "
                         "repro.launch.worker --connect HOST:PORT) instead "
                         "of spawning local subprocesses")
    ap.add_argument("--draft", default="",
                    help="with --cluster: arch name of a coordinator-side "
                         "draft model for greedy speculative decoding "
                         "(must share the target's vocab)")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="with --draft: draft tokens proposed per verify "
                         "round-trip (gamma)")
    ap.add_argument("--serve", default="",
                    help="with --cluster: HOST:PORT for the OpenAI-"
                         "compatible HTTP front door (SSE streaming; "
                         "port 0 picks an ephemeral port, printed on "
                         "startup) instead of a one-shot batch")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="with --serve: 429 past this many accepted-but-"
                         "unfinished requests")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="with --serve: TTFT SLO for the served summary "
                         "(0 = none)")
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0,
                    help="with --serve: mean-TPOT SLO for the served "
                         "summary (0 = none)")
    ap.add_argument("--direct-links", action="store_true",
                    help="with --transport socket: stage workers forward "
                         "activation frames to the next stage's worker over "
                         "peer TCP links; only tokens return to the "
                         "coordinator")
    ap.add_argument("--autoscale", action="store_true",
                    help="with --serve: run the live autoscaler (mix-solve "
                         "measured traffic, grow/shrink/reweight through "
                         "apply_plan)")
    ap.add_argument("--autoscale-interval-s", type=float, default=2.0,
                    help="with --autoscale: sampling interval")
    ap.add_argument("--autoscale-patience", type=int, default=2,
                    help="with --autoscale: consecutive overloaded samples "
                         "before scaling")
    ap.add_argument("--autoscale-window-s", type=float, default=15.0,
                    help="with --autoscale: arrival-rate trailing window")
    ap.add_argument("--autoscale-node-rate", type=float, default=0.0,
                    help="with --autoscale: cap each device type's modeled "
                         "tokens/s at this value (smoke runs on tiny CPU "
                         "models look infinitely fast to the paper-profile "
                         "table otherwise; 0 = use real device profiles)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.cluster:
        run_cluster(cfg, args)
        return
    if args.paged:
        run_paged(cfg, args)
        return
    dims = tuple(int(x) for x in args.mesh.split(",")) if args.mesh \
        else (jax.device_count(), 1)
    axes = ("data", "model")[:len(dims)] if len(dims) == 2 \
        else ("pod", "data", "model")
    mesh = jax.make_mesh(dims, axes)
    print(f"mesh {dict(zip(axes, dims))}; serving {cfg.name}")

    params_abs, params_axes = abstract_params(cfg)
    params_sh = tree_shardings(params_abs, params_axes, SERVE_RULES, mesh)

    with mesh:
        params = jax.jit(lambda k: init(cfg, k),
                         out_shardings=params_sh)(jax.random.key(0))
        rng = np.random.RandomState(0)
        tokens = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                         size=(args.batch, args.prompt)),
                             jnp.int32)
        kw = {}
        if cfg.is_encoder_decoder:
            kw["encoder_frames"] = jnp.asarray(
                rng.randn(args.batch, 16, cfg.d_model), jnp.bfloat16)

        t0 = time.time()
        logits, caches = jax.jit(
            lambda p, t: prefill(cfg, p, t, max_len=args.max_len, **kw)
        )(params, tokens)
        print(f"prefill: {time.time() - t0:.2f}s")
        dec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))
        out = [np.asarray(jnp.argmax(logits, -1))]
        pos = jnp.full((args.batch,), args.prompt, jnp.int32)
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            logits, caches = dec(params, jnp.asarray(out[-1]), caches,
                                 pos + i)
            out.append(np.asarray(jnp.argmax(logits, -1)))
        dt = time.time() - t0
        print(f"decode: {args.new_tokens - 1} steps in {dt:.2f}s "
              f"({(args.new_tokens - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
        print("sampled ids:", np.stack(out, 1)[:2].tolist())


if __name__ == "__main__":
    main()
