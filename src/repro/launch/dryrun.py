import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

The two lines above MUST run before any other import (jax locks the device
count on first backend init).  512 placeholder host devices back the
(16,16) single-pod and (2,16,16) multi-pod meshes.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun
  python -m repro.launch.dryrun --arch smollm_360m --shape train_4k --mesh single

Per cell this writes JSON with:
  flops / bytes (compiled.cost_analysis, per-device local),
  collective op bytes by kind (parsed from compiled.as_text()),
  memory_analysis (if the backend provides it),
  per-device bytes of params / caches / optimizer state (from shardings).
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import SHAPES, Cell, build_cell, cell_applicable
from repro.roofline.analysis import FlopsOptions, roofline_terms
from repro.roofline.hlo import collective_totals


def shard_bytes(tree, shardings, num_devices: int) -> float:
    """Per-device bytes of a sharded pytree."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        frac = 1.0
        if isinstance(sh, jax.sharding.NamedSharding):
            spec = sh.spec
            for i, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = (entry,) if isinstance(entry, str) else entry
                for a in axes:
                    frac /= sh.mesh.shape[a]
        total += n * leaf.dtype.itemsize * frac
    return total


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir=None,
             extra=None, tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    record = {"arch": arch, "shape": shape, "mesh": mesh_kind,
              "mesh_shape": dict(zip(mesh.axis_names,
                                     [int(mesh.shape[a]) for a in mesh.axis_names])),
              "tag": tag, "status": "ok"}
    try:
        from repro.launch.steps import tuned_config
        cfg = tuned_config(get_config(arch), extra or {})
        ok, reason = cell_applicable(cfg, shape)
        if not ok:
            record["status"] = "skipped"
            record["reason"] = reason
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                suffix = f"__{tag}" if tag else ""
                with open(os.path.join(
                        out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json"),
                        "w") as f:
                    json.dump(record, f, indent=1, default=str)
            return record
        cell = build_cell(arch, shape, mesh, extra=extra)
        record["description"] = cell.description
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

        try:
            mem = compiled.memory_analysis()
            record["memory_analysis"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # backend may not support it
            record["memory_analysis"] = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            record["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
        except Exception as e:
            record["cost_analysis"] = {"error": str(e)}
        hlo = compiled.as_text()
        coll_bytes, coll_count, _mults = collective_totals(hlo)
        record["collective_bytes"] = coll_bytes       # per-device, trip-adjusted
        record["collective_count"] = coll_count
        record["hlo_size_chars"] = len(hlo)

        num_devices = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        record["num_devices"] = num_devices
        info = SHAPES[shape]
        opts = FlopsOptions(
            skip_masked_chunks=bool((extra or {}).get("skip_masked_chunks")),
            moe_capacity_factor=getattr(cfg, "moe_capacity_factor", 1.25))
        record["roofline"] = roofline_terms(
            cfg, info["kind"], info["batch"], info["seq"], num_devices,
            collective_local_bytes=float(sum(coll_bytes.values())),
            opts=opts)
        record["params_bytes_per_device"] = shard_bytes(
            cell.args[0], cell.in_shardings[0], num_devices)
        if shape in ("decode_32k", "long_500k"):
            record["cache_bytes_per_device"] = shard_bytes(
                cell.args[2], cell.in_shardings[2], num_devices)
        if shape == "train_4k":
            record["opt_bytes_per_device"] = shard_bytes(
                cell.args[1], cell.in_shardings[1], num_devices)
        record["model_params"] = int(cfg.param_count())
        record["model_active_params"] = int(cfg.active_param_count())
        record["lower_s"] = round(t_lower - t0, 2)
        record["compile_s"] = round(t_compile - t_lower, 2)
    except Exception as e:
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    finally:
        record["total_s"] = round(time.time() - t0, 2)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}__{shape}__{mesh_kind}{suffix}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1, default=str)
    return record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="both", choices=["single", "multi",
                                                      "both"])
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--tag", default="")
    args = p.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, out_dir=args.out,
                               tag=args.tag)
                flops = rec.get("cost_analysis", {}).get("flops", 0)
                print(f"{arch:24s} {shape:12s} {mesh_kind:6s} "
                      f"{rec['status']:8s} "
                      f"compile={rec.get('compile_s', '-'):>7}s "
                      f"flops/dev={flops:.3e} "
                      f"coll={sum(rec.get('collective_bytes', {}).values())/1e6:10.1f}MB"
                      if rec["status"] == "ok" else
                      f"{arch:24s} {shape:12s} {mesh_kind:6s} "
                      f"{rec['status']:8s} {rec.get('reason', rec.get('error', ''))[:90]}",
                      flush=True)
                if rec["status"] == "failed":
                    failures += 1
    print(f"\ndone; failures={failures}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
