"""Launchers: production meshes, dry-run, train/serve drivers."""
from .mesh import make_production_mesh, make_test_mesh
