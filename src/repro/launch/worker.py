"""StageWorker: one Helix compute node as its own OS process.

The worker dials the coordinator (``--connect host:port``), then speaks the
length-prefixed frame protocol of ``repro.serving.transport``: every frame
is ``(method, args)`` and gets an ``("ok", result)`` or ``("err",
traceback)`` reply.  The first call is ``init``, which carries everything
the node needs — the model config, the full parameter tree, the assigned
``LayerRange``, the engine config, and the pool sizing the coordinator
derived from this node's VRAM — and builds the ``StageEngine`` /
``PagedStageEngine`` the remaining calls drive:

  stage(tag, payload)          stash an in-flight payload (prompt chunk /
                               activations) shipped by the SocketTransport;
                               a later engine call resolves the StagedRef
  prefill_stage / prefill_chunk / decode_stage / sample-side bookkeeping
                               the stage-engine API, argument-for-argument
  alloc_slot / free_slot / ensure / release / kv_tokens_* / pool_used
                               slot + KV bookkeeping the runtime's
                               admission and scheduler feedback use
  init                         (re)build the engine — a replan that moves
                               this node's slice re-inits over the same
                               connection
  ping / shutdown              liveness and clean exit

``ClusterRuntime.spawn_workers`` launches one of these per placed node as a
subprocess; for multi-host runs, start workers by hand on each machine and
point them at the coordinator's ``--connect`` address.
"""
from __future__ import annotations

import argparse
import socket
import traceback
from collections import OrderedDict
from typing import Any, Dict, List

from ..configs.base import BlockSpec, ModelConfig
from ..core.placement import LayerRange
from ..serving.engine import EngineConfig
from ..serving.stage_engine import DecodeItem, PagedStageEngine, StageEngine
from ..serving.transport import (FrameError, StagedRef, decode_payload,
                                 encode_payload, recv_frame, send_frame)

# staged payloads whose pass got cancelled (epoch bump) are never resolved;
# cap the stash so they can't accumulate across a long-lived worker
MAX_STAGED = 1024


def config_from_wire(d: Dict[str, Any]) -> ModelConfig:
    d = dict(d)
    d["pattern"] = tuple(BlockSpec(**dict(b)) for b in d["pattern"])
    d["prologue"] = tuple(BlockSpec(**dict(b)) for b in d["prologue"])
    return ModelConfig(**d)


class StageWorker:
    """Owns one node's stage engine plus the staging area for in-flight
    transport payloads."""

    def __init__(self):
        self.engine = None
        self.staged: "OrderedDict[int, Any]" = OrderedDict()
        self.node = "?"

    # -- staged payloads -------------------------------------------------
    def _resolve(self, x):
        if isinstance(x, StagedRef):
            try:
                return self.staged.pop(x.tag)
            except KeyError:
                raise RuntimeError(
                    f"staged payload {x.tag} missing on {self.node} "
                    "(never arrived, or evicted past the "
                    f"{MAX_STAGED}-entry cap)") from None
        return x

    def do_stage(self, tag: int, payload) -> None:
        self.staged[tag] = payload
        while len(self.staged) > MAX_STAGED:
            self.staged.popitem(last=False)     # oldest = cancelled passes

    # -- lifecycle -------------------------------------------------------
    def do_init(self, spec: Dict[str, Any]) -> str:
        cfg = config_from_wire(spec["cfg"])
        ec = EngineConfig(**dict(spec["ec"]))
        layers = LayerRange(*spec["layers"])
        self.node = spec.get("node", "?")
        if spec["paged"]:
            self.engine = PagedStageEngine(
                cfg, spec["params"], layers, ec,
                num_pages=spec["num_pages"], page_size=spec["page_size"],
                kv_dtype=spec.get("kv_dtype"),
                interpret=spec["interpret"], rng_seed=spec["rng_seed"])
        else:
            self.engine = StageEngine(cfg, spec["params"], layers, ec,
                                      rng_seed=spec["rng_seed"])
        self.staged.clear()
        return f"{self.node}: layers [{layers.start}, {layers.end})"

    # -- dispatch --------------------------------------------------------
    def handle(self, method: str, args: List[Any]):
        if method == "ping":
            return "pong"
        if method == "stage":
            return self.do_stage(args[0], args[1])
        if method == "init":
            return self.do_init(args[0])
        eng = self.engine
        if eng is None:
            raise RuntimeError(f"{method!r} before init")
        if method == "prefill_stage":
            slot, x, entry = args
            return eng.prefill_stage(slot, self._resolve(x), entry)
        if method == "prefill_chunk":
            slot, x, entry, start = args
            return eng.prefill_chunk(slot, self._resolve(x), entry, start)
        if method == "decode_stage":
            items = [DecodeItem(slot=s, pos=p, entry=e, token=t,
                                h=self._resolve(h))
                     for s, p, e, t, h in args[0]]
            return [(o.h, o.logits) for o in eng.decode_stage(items)]
        if method == "alloc_slot":
            return eng.alloc_slot(args[0])
        if method == "free_slot":
            return eng.free_slot(args[0])
        if method == "ensure":
            return eng.ensure(args[0], args[1])
        if method == "release":
            return eng.release(args[0])
        if method == "kv_tokens_used":
            return eng.kv_tokens_used()
        if method == "kv_tokens_capacity":
            return eng.kv_tokens_capacity()
        if method == "pool_used":
            return eng.pool_used()
        if method == "pool_num_pages":
            pool = getattr(eng, "pool", None)
            return pool.num_pages if pool is not None else None
        raise RuntimeError(f"unknown method {method!r}")


def serve_connection(sock: socket.socket) -> None:
    """Frame loop: one request, one reply, until shutdown or the
    coordinator goes away."""
    worker = StageWorker()
    while True:
        try:
            frame = recv_frame(sock)
        except socket.timeout:
            continue                     # idle coordinator, not a dead one:
                                         # keep waiting for the next frame
        except (FrameError, OSError):
            return                       # coordinator gone: exit quietly
        try:
            method, args = decode_payload(frame)
        except (FrameError, ValueError) as e:
            _reply(sock, ("err", f"undecodable request: {e}"))
            continue
        if method == "shutdown":
            _reply(sock, ("ok", None))
            return
        try:
            result = worker.handle(method, args)
        except Exception:
            _reply(sock, ("err", traceback.format_exc(limit=20)))
        else:
            _reply(sock, ("ok", result))


def _reply(sock: socket.socket, payload) -> None:
    try:
        send_frame(sock, encode_payload(payload))
    except (OSError, FrameError):
        pass                             # coordinator gone mid-reply


def run_worker(host: str, port: int, timeout_s: float = 300.0) -> None:
    sock = socket.create_connection((host, port), timeout=timeout_s)
    sock.settimeout(timeout_s)
    try:
        serve_connection(sock)
    finally:
        sock.close()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="coordinator address to dial (the coordinator "
                         "assigns this worker a node + layer slice over "
                         "the wire)")
    ap.add_argument("--timeout-s", type=float, default=300.0,
                    help="socket timeout for connect and mid-frame reads; "
                         "an idle-but-open connection waits forever (a "
                         "dead coordinator closes the socket, which exits "
                         "the worker)")
    args = ap.parse_args()
    host, _, port = args.connect.rpartition(":")
    run_worker(host or "127.0.0.1", int(port), timeout_s=args.timeout_s)


if __name__ == "__main__":
    main()
