"""StageWorker: one Helix compute node as its own OS process.

The worker dials the coordinator (``--connect host:port``), then speaks the
length-prefixed frame protocol of ``repro.serving.transport``: every frame
is ``(method, args)`` and gets an ``("ok", result)`` or ``("err",
traceback)`` reply.  The first call is ``init``, which carries everything
the node needs — the model config, the full parameter tree, the assigned
``LayerRange``, the engine config, and the pool sizing the coordinator
derived from this node's VRAM — and builds the ``StageEngine`` /
``PagedStageEngine`` the remaining calls drive:

  stage(tag, payload)          stash an in-flight payload (prompt chunk /
                               activations) shipped by the SocketTransport;
                               a later engine call resolves the StagedRef
  prefill_stage / prefill_chunk / decode_stage / sample-side bookkeeping
                               the stage-engine API, argument-for-argument;
                               each compute call accepts a trailing forward
                               spec ``(dst_node, tag)`` — the worker pushes
                               the output straight into the destination
                               worker's staging area over a **peer channel**
                               before replying, so the activation frame
                               never rides back through the coordinator
  export_kv / import_kv        KV handoff between prefill and decode
                               replicas (disaggregated serving); export
                               honours the same forward spec
  peer_addr / set_peers        worker-to-worker wiring: ``peer_addr`` opens
                               a lazy listening socket and returns its port;
                               ``set_peers`` installs the routed topology
                               ({node: (host, port)}) the forwards dial
  alloc_slot / free_slot / ensure / release / kv_tokens_* / pool_used
                               slot + KV bookkeeping the runtime's
                               admission and scheduler feedback use
  init                         (re)build the engine — a replan that moves
                               this node's slice re-inits over the same
                               connection
  ping / shutdown              liveness and clean exit

``ClusterRuntime.spawn_workers`` launches one of these per placed node as a
subprocess; for multi-host runs, start workers by hand on each machine and
point them at the coordinator's ``--connect`` address.

Concurrency: the coordinator connection and every accepted peer connection
run their own frame loop against ONE shared ``StageWorker``; engine calls
and staging are serialized by a worker lock.  Peer pushes happen *outside*
that lock, so a worker waiting on a peer's ack never blocks the peer's own
compute — and since forwards only ever point down the layer order (and
prefill -> decode for KV handoffs), the forwarding graph is acyclic and
cannot deadlock.
"""
from __future__ import annotations

import argparse
import socket
import threading
import traceback
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from ..configs.base import BlockSpec, ModelConfig
from ..core.placement import LayerRange
from ..serving.engine import EngineConfig
from ..serving.stage_engine import DecodeItem, PagedStageEngine, StageEngine
from ..serving.transport import (FrameError, StagedRef, WorkerChannel,
                                 WorkerDied, decode_payload, encode_payload,
                                 recv_frame, send_frame)

# staged payloads whose pass got cancelled (epoch bump) are never resolved;
# cap the stash so they can't accumulate across a long-lived worker
MAX_STAGED = 1024


def config_from_wire(d: Dict[str, Any]) -> ModelConfig:
    d = dict(d)
    d["pattern"] = tuple(BlockSpec(**dict(b)) for b in d["pattern"])
    d["prologue"] = tuple(BlockSpec(**dict(b)) for b in d["prologue"])
    return ModelConfig(**d)


class StageWorker:
    """Owns one node's stage engine plus the staging area for in-flight
    transport payloads, and (when the coordinator wires a routed topology)
    the peer channels direct forwards travel over."""

    def __init__(self):
        self.engine = None
        self.staged: "OrderedDict[int, Any]" = OrderedDict()
        self.node = "?"
        self._lock = threading.RLock()      # engine + staging serialization
        self._peer_lock = threading.Lock()  # peer wiring
        self.peer_addrs: Dict[str, Tuple[str, int]] = {}
        self.peers: Dict[str, WorkerChannel] = {}
        self._listener: Optional[socket.socket] = None

    # -- peer wiring -----------------------------------------------------
    def do_peer_addr(self) -> int:
        """Open (once) the listening socket other workers forward into;
        returns its port.  The coordinator learns the host from this
        worker's connection address and distributes {node: (host, port)}
        maps via ``set_peers``."""
        with self._peer_lock:
            if self._listener is None:
                srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
                srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
                srv.bind(("0.0.0.0", 0))
                srv.listen(16)
                self._listener = srv
                threading.Thread(target=self._accept_peers,
                                 name=f"peers-{self.node}",
                                 daemon=True).start()
            return self._listener.getsockname()[1]

    def _accept_peers(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.settimeout(300.0)
            threading.Thread(target=serve_connection, args=(conn,),
                             kwargs={"worker": self}, daemon=True).start()

    def do_set_peers(self, addrs: Dict[str, Any]) -> None:
        """Install the routed topology.  Channels to nodes whose address
        changed (replan moved or respawned them) are dropped and re-dialed
        lazily."""
        with self._peer_lock:
            new = {n: (str(h), int(p)) for n, (h, p) in addrs.items()}
            for n, ch in list(self.peers.items()):
                if self.peer_addrs.get(n) != new.get(n):
                    ch.close()
                    del self.peers[n]
            self.peer_addrs = new

    def _peer(self, node: str) -> WorkerChannel:
        with self._peer_lock:
            ch = self.peers.get(node)
            if ch is not None and ch.alive:
                return ch
            addr = self.peer_addrs.get(node)
            if addr is None:
                raise RuntimeError(
                    f"{self.node}: no peer address for {node} — "
                    "coordinator never sent set_peers for this topology")
            s = socket.create_connection(addr, timeout=60.0)
            ch = WorkerChannel(s, node=f"{self.node}->{node}",
                               timeout_s=60.0)
            self.peers[node] = ch
            return ch

    # -- staged payloads -------------------------------------------------
    def _resolve(self, x):
        if isinstance(x, StagedRef):
            try:
                return self.staged.pop(x.tag)
            except KeyError:
                raise RuntimeError(
                    f"staged payload {x.tag} missing on {self.node} "
                    "(never arrived, or evicted past the "
                    f"{MAX_STAGED}-entry cap)") from None
        return x

    def do_stage(self, tag: int, payload) -> None:
        self.staged[tag] = payload
        while len(self.staged) > MAX_STAGED:
            self.staged.popitem(last=False)     # oldest = cancelled passes

    # -- lifecycle -------------------------------------------------------
    def do_init(self, spec: Dict[str, Any]) -> str:
        cfg = config_from_wire(spec["cfg"])
        ec = EngineConfig(**dict(spec["ec"]))
        layers = LayerRange(*spec["layers"])
        self.node = spec.get("node", "?")
        if spec["paged"]:
            self.engine = PagedStageEngine(
                cfg, spec["params"], layers, ec,
                num_pages=spec["num_pages"], page_size=spec["page_size"],
                kv_dtype=spec.get("kv_dtype"),
                interpret=spec["interpret"], rng_seed=spec["rng_seed"])
        else:
            self.engine = StageEngine(cfg, spec["params"], layers, ec,
                                      rng_seed=spec["rng_seed"])
        self.staged.clear()
        return f"{self.node}: layers [{layers.start}, {layers.end})"

    # -- dispatch --------------------------------------------------------
    def handle(self, method: str, args: List[Any]):
        if method == "ping":
            return "pong"
        if method == "peer_addr":
            return self.do_peer_addr()
        if method == "set_peers":
            return self.do_set_peers(dict(args[0]))
        pushes: List[Tuple[str, int, Any]] = []
        with self._lock:
            result = self._dispatch(method, args, pushes)
        # peer pushes run OUTSIDE the worker lock: waiting on a peer's ack
        # must never block that peer's own compute against us
        for dst, tag, payload in pushes:
            try:
                self._peer(dst).call("stage", tag, payload)
            except (WorkerDied, OSError):
                # peer gone: drop the frame — the coordinator's failover
                # requeues the pass and epoch guards kill the stale
                # delivery, matching the transport pump's drop semantics
                pass
        return result

    def _dispatch(self, method: str, args: List[Any],
                  pushes: List[Tuple[str, int, Any]]):
        if method == "stage":
            return self.do_stage(args[0], args[1])
        if method == "init":
            return self.do_init(args[0])
        eng = self.engine
        if eng is None:
            raise RuntimeError(f"{method!r} before init")
        if method == "prefill_stage":
            slot, x, entry = args[:3]
            fwd = args[3] if len(args) > 3 else None
            out = eng.prefill_stage(slot, self._resolve(x), entry)
            if fwd is not None:
                pushes.append((fwd[0], fwd[1], out))
                return None
            return out
        if method == "prefill_chunk":
            slot, x, entry, start = args[:4]
            fwd = args[4] if len(args) > 4 else None
            out = eng.prefill_chunk(slot, self._resolve(x), entry, start)
            if fwd is not None:
                pushes.append((fwd[0], fwd[1], out))
                return None
            return out
        if method == "decode_stage":
            # wire items are 6-tuples since speculative decoding: a trailing
            # ``tokens`` vector marks a multi-token verify pass; both ``h``
            # and ``tokens`` may arrive as StagedRefs pushed by a peer
            items = []
            for w in args[0]:
                s, p, e, t, h = w[:5]
                tk = self._resolve(w[5]) if len(w) > 5 and w[5] is not None \
                    else None
                items.append(DecodeItem(
                    slot=s, pos=p, entry=e, token=t, h=self._resolve(h),
                    tokens=None if tk is None else [int(x) for x in tk]))
            fwds = args[1] if len(args) > 1 else None
            outs = eng.decode_stage(items)
            reply = []
            for i, o in enumerate(outs):
                f = fwds[i] if fwds else None
                if f is not None:
                    pushes.append((f[0], f[1], o.h))
                    reply.append((None, o.logits))
                else:
                    reply.append((o.h, o.logits))
            return reply
        if method == "export_kv":
            slot, tokens, layers = args[:3]
            fwd = args[3] if len(args) > 3 else None
            out = eng.export_kv(slot, tokens, list(layers))
            if fwd is not None:
                pushes.append((fwd[0], fwd[1], out))
                return None
            return out
        if method == "import_kv":
            slot, tokens, payload = args
            return eng.import_kv(slot, tokens, self._resolve(payload))
        if method == "alloc_slot":
            return eng.alloc_slot(args[0])
        if method == "free_slot":
            return eng.free_slot(args[0])
        if method == "ensure":
            return eng.ensure(args[0], args[1])
        if method == "release":
            return eng.release(args[0])
        if method == "rollback":
            return eng.rollback(args[0], args[1])
        if method == "kv_tokens_used":
            return eng.kv_tokens_used()
        if method == "kv_tokens_capacity":
            return eng.kv_tokens_capacity()
        if method == "pool_used":
            return eng.pool_used()
        if method == "pool_num_pages":
            pool = getattr(eng, "pool", None)
            return pool.num_pages if pool is not None else None
        raise RuntimeError(f"unknown method {method!r}")


def serve_connection(sock: socket.socket,
                     worker: Optional[StageWorker] = None) -> None:
    """Frame loop: one request, one reply, until shutdown or the peer goes
    away.  The coordinator connection creates the worker; accepted peer
    connections share it (so peer-staged payloads land in the same stash
    the engine RPCs resolve from)."""
    if worker is None:
        worker = StageWorker()
    while True:
        try:
            frame = recv_frame(sock)
        except socket.timeout:
            continue                     # idle coordinator, not a dead one:
                                         # keep waiting for the next frame
        except (FrameError, OSError):
            return                       # coordinator gone: exit quietly
        try:
            method, args = decode_payload(frame)
        except (FrameError, ValueError) as e:
            _reply(sock, ("err", f"undecodable request: {e}"))
            continue
        if method == "shutdown":
            _reply(sock, ("ok", None))
            return
        try:
            result = worker.handle(method, args)
        except Exception:
            _reply(sock, ("err", traceback.format_exc(limit=20)))
        else:
            _reply(sock, ("ok", result))


def _reply(sock: socket.socket, payload) -> None:
    try:
        send_frame(sock, encode_payload(payload))
    except (OSError, FrameError):
        pass                             # coordinator gone mid-reply


def run_worker(host: str, port: int, timeout_s: float = 300.0) -> None:
    sock = socket.create_connection((host, port), timeout=timeout_s)
    sock.settimeout(timeout_s)
    try:
        serve_connection(sock)
    finally:
        sock.close()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="coordinator address to dial (the coordinator "
                         "assigns this worker a node + layer slice over "
                         "the wire)")
    ap.add_argument("--timeout-s", type=float, default=300.0,
                    help="socket timeout for connect and mid-frame reads; "
                         "an idle-but-open connection waits forever (a "
                         "dead coordinator closes the socket, which exits "
                         "the worker)")
    args = ap.parse_args()
    host, _, port = args.connect.rpartition(":")
    run_worker(host or "127.0.0.1", int(port), timeout_s=args.timeout_s)


if __name__ == "__main__":
    main()
