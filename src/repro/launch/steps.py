"""Step builders + input specs for every (architecture x shape) cell.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins (no
allocation); ``build_cell(arch, shape, mesh)`` returns the jitted-but-
unlowered step function plus in/out shardings and abstract args, ready for
``.lower(...).compile()`` in dryrun.py.

Shape semantics (assignment):
  train_4k     -> train_step   (tokens+labels, global_batch x seq)
  prefill_32k  -> prefill      (prompt processing, returns decode caches)
  decode_32k   -> serve_step   (one new token, KV cache of seq_len)
  long_500k    -> serve_step   (batch=1, 512k KV; sequence-parallel rules)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from ..configs import get_config
from ..configs.base import ModelConfig
from ..dist.sharding import (LONG_CONTEXT_RULES, SERVE_RULES, TRAIN_RULES,
                             ShardingRules, moe_variant, opt_state_shardings,
                             tree_shardings)
from ..models import model as M
from ..models.common import abstract_shapes, logical_axes
from ..training.optimizer import OptimizerConfig, opt_init
from ..training.train_step import TrainConfig, make_train_step

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1,
                  "rules": "long"},
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape == "long_500k":
        if cfg.pure_full_attention:
            return False, ("pure full-attention arch: 512k decode KV is "
                           "quadratic-prefill territory; skipped per "
                           "assignment (see DESIGN.md)")
        if cfg.is_encoder_decoder:
            return False, "encoder-decoder: decoder positions << 512k"
    return True, ""


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    """Adafactor >=30B (Adam state would not fit 16GB/chip), AdamW below."""
    if cfg.param_count() >= 30e9:
        return OptimizerConfig(name="adafactor", lr=1e-4)
    return OptimizerConfig(name="adamw", lr=3e-4)


def rules_for(shape: str, kind: str,
              cfg: Optional[ModelConfig] = None) -> ShardingRules:
    if SHAPES[shape].get("rules") == "long":
        base = LONG_CONTEXT_RULES
    else:
        base = TRAIN_RULES if kind == "train" else SERVE_RULES
    if cfg is not None and cfg.moe_num_experts and kind != "train":
        return moe_variant(base)
    return base


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    specs = M.param_specs(cfg)
    return abstract_shapes(specs, cfg.param_dtype), logical_axes(specs)


def input_specs(arch: str, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    i32 = jnp.int32
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.is_encoder_decoder:
            out["encoder_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16)
    elif kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.is_encoder_decoder:
            out["encoder_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((B,), i32)
        out["cache_pos"] = jax.ShapeDtypeStruct((B,), i32)
    return out


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int):
    shapes = jax.eval_shape(
        lambda: M.init_caches(cfg, batch, max_len,
                              src_len=cfg.max_source_positions
                              if cfg.is_encoder_decoder else None))
    axes = M.cache_axes(cfg)
    return shapes, axes




# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable                     # to be jitted
    args: Tuple                      # abstract args (ShapeDtypeStruct trees)
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    description: str


def tuned_config(cfg: ModelConfig, extra: Dict[str, Any]) -> ModelConfig:
    """Hillclimb knobs that alter the model structure.

    pad_q_heads: pad query heads (zero-padded W_q/W_o rows — exact math for
    interleave-padded checkpoints) so head count divides the TP axis.
    """
    pad = extra.get("pad_q_heads")
    if pad:
        cfg = dataclasses.replace(cfg, num_heads=int(pad),
                                  head_dim=cfg.resolved_head_dim)
    groups = extra.get("moe_groups")
    if groups:
        cfg = dataclasses.replace(cfg, moe_groups=int(groups))
    dcf = extra.get("decode_capacity_factor")
    if dcf:
        cfg = dataclasses.replace(cfg, moe_decode_drop_free=False,
                                  moe_capacity_factor=float(dcf))
    return cfg


def tuned_rules(rules: ShardingRules, extra: Dict[str, Any]) -> ShardingRules:
    """Hillclimb knobs on the sharding rules.

    no_head_dim_shard: drop head_dim->model (use when q-heads shard instead;
    head_dim sharding forces a scores-psum per attention chunk).
    """
    out = []
    for name, ax in rules.rules:
        if name == "head_dim" and extra.get("no_head_dim_shard"):
            out.append((name, None))
        elif name == "embed" and extra.get("embed_shard"):
            out.append((name, extra["embed_shard"]))
        elif name == "seq" and extra.get("cache_seq_shard"):
            # decode: shard KV/MLA caches along sequence over the model axis
            # (distributed softmax-combine is KB-sized; rank/head sharding
            # psums scores-sized partials instead)
            out.append((name, "model"))
        elif name == "lora" and extra.get("cache_seq_shard"):
            out.append((name, None))
        else:
            out.append((name, ax))
    return ShardingRules(rules=tuple(out))


def build_cell(arch: str, shape: str, mesh: Mesh,
               extra: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = get_config(arch)
    info = SHAPES[shape]
    kind = info["kind"]
    B, S = info["batch"], info["seq"]
    extra = extra or {}
    cfg = tuned_config(cfg, extra)
    rules = tuned_rules(rules_for(shape, kind, cfg), extra)
    # install activation-sharding hints for model-side constraints
    from ..models import partition
    partition.set_mesh_rules(mesh, rules)

    params_abs, params_axes = abstract_params(cfg)
    params_sh = tree_shardings(params_abs, params_axes, rules, mesh)
    inputs = input_specs(arch, shape)

    if kind == "train":
        opt_cfg = extra.get("optimizer") or optimizer_for(cfg)
        tc = TrainConfig(optimizer=opt_cfg,
                         remat=extra.get("remat", "full"),
                         microbatches=extra.get("microbatches", 1),
                         skip_masked_chunks=bool(
                             extra.get("skip_masked_chunks")))
        step = make_train_step(cfg, tc)
        opt_abs = jax.eval_shape(functools.partial(opt_init, tc.optimizer),
                                 params_abs)
        opt_sh = opt_state_shardings(tc.optimizer, params_abs, params_axes,
                                     params_sh, rules, mesh)
        batch_sh = {
            k: NamedSharding(mesh, rules.spec(
                ("batch", "seq", "embed")[:v.ndim], mesh, v.shape))
            for k, v in inputs.items()}
        return Cell(
            arch=arch, shape=shape, fn=step,
            args=(params_abs, opt_abs, inputs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
            description=f"train_step {arch} {B}x{S} opt={tc.optimizer.name}")

    if kind == "prefill":
        skip = bool(extra.get("skip_masked_chunks"))

        def prefill_fn(params, batch):
            return M.prefill(cfg, params, batch["tokens"], max_len=S,
                             encoder_frames=batch.get("encoder_frames"),
                             skip_masked_chunks=skip)
        batch_sh = {
            k: NamedSharding(mesh, rules.spec(
                ("batch", "seq", "embed")[:v.ndim], mesh, v.shape))
            for k, v in inputs.items()}
        return Cell(
            arch=arch, shape=shape, fn=prefill_fn,
            args=(params_abs, inputs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=None,
            donate_argnums=(),
            description=f"prefill {arch} {B}x{S}")

    # decode
    caches_abs, caches_axes = abstract_caches(cfg, B, S)
    caches_sh = tree_shardings(caches_abs, caches_axes, rules, mesh)
    tok_sh = NamedSharding(mesh, rules.spec(("batch",), mesh, (B,)))

    def decode_fn(params, tokens, caches, cache_pos):
        return M.decode_step(cfg, params, tokens, caches, cache_pos)

    return Cell(
        arch=arch, shape=shape, fn=decode_fn,
        args=(params_abs, inputs["tokens"], caches_abs, inputs["cache_pos"]),
        in_shardings=(params_sh, tok_sh, caches_sh, tok_sh),
        out_shardings=(None, caches_sh),
        donate_argnums=(2,),
        description=f"serve_step {arch} batch={B} kv={S}")
