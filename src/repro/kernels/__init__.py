"""Pallas TPU kernels for serving hot spots (validated via interpret=True).

flash_attention — prefill causal/windowed GQA attention
paged_attention — decode over paged KV pool (TPU-native vLLM PagedAttention)
"""
