"""Pallas TPU paged attention (decode) — TPU-native vLLM PagedAttention.

Hardware adaptation (DESIGN.md §3): the CUDA kernel's warp-level gather has
no TPU analogue; instead the page table rides in SMEM as a *scalar-prefetch*
operand (PrefetchScalarGridSpec) and the BlockSpec index_map dereferences it,
so the pipeline's async copies stream exactly the pages each sequence needs
HBM->VMEM.  Online-softmax accumulators live in VMEM scratch across the
(sequential) page axis of the grid.

Grid: (B, NP).  Per step the kernel sees one (page, KH, D) K/V tile and the
(H, D) query for that sequence; all query heads for a kv head are processed
together (GQA groups stay in VREGs).

Variable-context streaming: the grid stays the static worst case (B, NP) —
jit-friendly, one compiled program for any batch mix — but the K/V index
maps clamp the page coordinate at each sequence's last *active* page
(``ceil(length / page) - 1``).  Pallas elides the HBM->VMEM copy whenever an
index map returns the same block index as the previous grid step, so steps
past a sequence's live context re-reference the last active page and move no
bytes; ``@pl.when(ip * page < length)`` already skipped their compute.  Per
launch the kernel therefore streams ``sum_b max(ceil(len_b/page), 1)`` pages
instead of ``B * NP`` (see ``ops.streamed_pages_per_step``).

Int8 KV: when per-page, per-kv-head scales are passed, K/V pages are int8
and dequantized in-VMEM inside ``_compute`` (one (KH,)-scale row per page,
riding the same clamped index map), halving decode HBM traffic again.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables, lengths, q_ref, *refs, page: int, num_pages: int,
            groups: int, scale: float, quantized: bool):
    if quantized:
        k_ref, ks_ref, v_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    ip = pl.program_id(1)
    length = lengths[b]

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ip * page < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (H, D)
        k = k_ref[0].astype(jnp.float32)                  # (page, KH, D)
        if quantized:
            k = k * ks_ref[0][None, :, None]              # in-VMEM dequant
        H, D = q.shape
        KH = k.shape[1]
        qg = q.reshape(KH, groups, D)
        # scores: (KH, G, page)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)           # (KH, G, page)
        pos = ip * page + jax.lax.broadcasted_iota(
            jnp.int32, (KH, groups, page), 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]                               # (KH, G)
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        v = v_ref[0].astype(jnp.float32)                  # (page, KH, D)
        if quantized:
            v = v * vs_ref[0][None, :, None]
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)           # (KH, G, D)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv
        m_scr[...] = m_new

    @pl.when(ip == num_pages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)            # (KH, G)
        out = acc_scr[...] / denom[..., None]             # (KH, G, D)
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    k_scales: jax.Array | None = None,
                    v_scales: jax.Array | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,D); k/v_pages: (P,page,KH,D); block_tables: (B,NP);
    lengths: (B,) -> (B,H,D).

    ``k_scales``/``v_scales``: optional (P, KH) float32 per-page per-kv-head
    absmax scales — when given, pages are int8 and dequantized in-VMEM.
    """
    B, H, D = q.shape
    P, page, KH, _ = k_pages.shape
    NP = block_tables.shape[1]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    quantized = k_scales is not None
    if quantized and v_scales is None:
        raise ValueError("k_scales given without v_scales")

    def page_id(b, ip, bt, ln):
        # clamp at the last active page: steps past ceil(len/page) re-issue
        # the same index, so the pipeline elides their HBM->VMEM copy
        last = jnp.maximum((ln[b] + page - 1) // page - 1, 0)
        return bt[b, jnp.minimum(ip, last)]

    kv_spec = pl.BlockSpec(
        (1, page, KH, D), lambda b, ip, bt, ln: (page_id(b, ip, bt, ln),
                                                 0, 0, 0))
    scale_spec = pl.BlockSpec(
        (1, KH), lambda b, ip, bt, ln: (page_id(b, ip, bt, ln), 0))
    q_spec = pl.BlockSpec((1, H, D), lambda b, ip, bt, ln: (b, 0, 0))

    kernel = functools.partial(_kernel, page=page, num_pages=NP,
                               groups=G, scale=scale, quantized=quantized)
    if quantized:
        in_specs = [q_spec, kv_spec, scale_spec, kv_spec, scale_spec]
        operands = (q, k_pages, k_scales, v_pages, v_scales)
    else:
        in_specs = [q_spec, kv_spec, kv_spec]
        operands = (q, k_pages, v_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, NP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, D), lambda b, ip, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KH, G), jnp.float32),
            pltpu.VMEM((KH, G), jnp.float32),
            pltpu.VMEM((KH, G, D), jnp.float32),
        ],
    )
    out_dtype = q.dtype
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), out_dtype),
        interpret=interpret,
    )(block_tables, lengths, *operands)
