from .kernel import paged_attention
from .ops import dense_to_pages, paged_attention_op, streamed_pages_per_step
from .quant import dequantize_kv_pages, quantize_kv_pages, quantized_append
from .ref import paged_attention_ref
