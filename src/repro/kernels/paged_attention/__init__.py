from .kernel import paged_attention
from .ops import dense_to_pages, paged_attention_op
from .ref import paged_attention_ref
