"""Jit'd wrapper + page-pool utilities for paged attention decode.

The serving-side allocator that feeds this kernel (on-demand pages, block
tables, admission control) lives in ``repro.serving.kv_pool.PagePool``;
``repro.models.paged`` is the model-level consumer (``gqa_decode_paged``).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import paged_attention
from .ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths, *,
                       interpret: bool = False):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           interpret=interpret)


def dense_to_pages(k: jax.Array, v: jax.Array, lengths, page: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pack dense (B,S,KH,D) caches into a page pool + block tables
    (testing/migration helper; a real server allocates pages on demand)."""
    B, S, KH, D = k.shape
    assert S % page == 0
    npages = S // page
    k_pages = k.reshape(B * npages, page, KH, D)
    v_pages = v.reshape(B * npages, page, KH, D)
    block_tables = jnp.arange(B * npages, dtype=jnp.int32).reshape(B, npages)
    return k_pages, v_pages, block_tables
