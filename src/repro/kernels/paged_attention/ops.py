"""Jit'd wrapper + page-pool utilities for paged attention decode.

The serving-side allocator that feeds this kernel (on-demand pages, block
tables, admission control) lives in ``repro.serving.kv_pool.PagePool``;
``repro.models.paged`` is the model-level consumer (``gqa_decode_paged``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import paged_attention
from .ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths,
                       k_scales=None, v_scales=None, *,
                       interpret: bool = False):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           k_scales=k_scales, v_scales=v_scales,
                           interpret=interpret)


def streamed_pages_per_step(lengths, page: int) -> int:
    """Pages the variable-context kernel copies HBM->VMEM per launch.

    The grid stays (B, NP), but the clamped index map re-issues the last
    active page index past ``ceil(len/page)`` and Pallas elides copies whose
    index matches the previous grid step — so traffic follows the *live*
    context: ``sum_b max(ceil(len_b / page), 1)`` pages (the fixed-grid
    kernel streamed ``B * NP``)."""
    l = np.asarray(lengths)
    return int(np.maximum(-(-l // page), 1).sum())


def dense_to_pages(k: jax.Array, v: jax.Array, lengths, page: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pack dense (B,S,KH,D) caches into a page pool + block tables
    (testing/migration helper; a real server allocates pages on demand)."""
    B, S, KH, D = k.shape
    assert S % page == 0
    npages = S // page
    k_pages = k.reshape(B * npages, page, KH, D)
    v_pages = v.reshape(B * npages, page, KH, D)
    block_tables = jnp.arange(B * npages, dtype=jnp.int32).reshape(B, npages)
    return k_pages, v_pages, block_tables
