"""Int8 KV-page quantization: per-page, per-kv-head absmax scales.

Same absmax idiom as ``dist.collectives.quantize_int8`` (the compressed
pipeline-parallel collectives), but at page granularity: a (P, page, KH, D)
pool quantizes to int8 with one float32 scale per (page, kv_head) — K and V
separately — so the decode kernel dequantizes in-VMEM with a (KH,) scale row
that rides the same scalar-prefetched block-table index as the page itself.

Appends are read-modify-write at page granularity (``quantized_append``):
the touched window of pages is gathered, dequantized, the new rows inserted,
and the window requantized.  Rows past the append point are zeroed before
requantization, so a freshly allocated page never inherits a stale absmax
from its previous owner, and a page's scale is a function of its live
contents only.  Since appends only add rows, a page's absmax — hence its
scale — is non-decreasing over a sequence's lifetime: requantizing already
quantized rows with an unchanged scale is exact, so drift is bounded by the
handful of steps where a new row actually raises the page's absmax.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_TINY = 1e-8


def quantize_kv_pages(pages: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(..., page, KH, D) float -> (int8 pages, (..., KH) float32 scales)."""
    f = pages.astype(jnp.float32)
    amax = jnp.max(jnp.abs(f), axis=(-3, -1))
    scales = jnp.maximum(amax / 127.0, _TINY)
    q = jnp.clip(jnp.round(f / scales[..., None, :, None]),
                 -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_kv_pages(q: jax.Array, scales: jax.Array,
                        dtype=jnp.float32) -> jax.Array:
    """Inverse of ``quantize_kv_pages``; scales broadcast over (page, D)."""
    return (q.astype(jnp.float32)
            * scales[..., None, :, None].astype(jnp.float32)).astype(dtype)


def quantized_append(pages: jax.Array, scales: jax.Array,
                     block_table: jax.Array, start: jax.Array,
                     rows: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Append ``rows`` (B, C, KH, D) at contiguous positions
    ``start .. start+C-1`` of each sequence's paged KV.

    pages: (P, page, KH, D) int8; scales: (P, KH) f32; block_table: (B, NP)
    int32; start: (B,) int32.  Returns (pages, scales) updated.

    The touched window is at most ``1 + ceil((C-1+page-1)/page)`` pages per
    row (static), gathered with the straddle handled by masking: window
    slots holding no appended row — and slots past the table — are redirected
    to scratch page 0, so real untouched pages are never requantized.
    Positions ``>= start + C`` inside the window are zeroed before
    requantization (stale data from a page's previous owner must not inflate
    the fresh scale).
    """
    P, page, KH, D = pages.shape
    B, C = rows.shape[:2]
    NP = block_table.shape[1]
    NT = 1 + (C + page - 2) // page          # touched pages incl. straddle
    loc0 = start // page                     # (B,) first touched block
    w = start % page                         # (B,) offset inside it
    locs = loc0[:, None] + jnp.arange(NT)[None, :]            # (B, NT)
    touched = (jnp.arange(NT)[None, :] * page) < (w[:, None] + C)
    valid = touched & (locs < NP)
    pids = jnp.take_along_axis(block_table, jnp.clip(locs, 0, NP - 1), axis=1)
    pids = jnp.where(valid, pids, 0)                          # (B, NT)

    win = dequantize_kv_pages(pages[pids], scales[pids])      # (B,NT,pg,KH,D)
    win = win.reshape(B, NT * page, KH, D)
    gpos = loc0[:, None] * page + jnp.arange(NT * page)[None, :]
    win = jnp.where((gpos < start[:, None] + C)[..., None, None], win, 0.0)
    idx = w[:, None] + jnp.arange(C)[None, :]                 # (B, C)
    win = win.at[jnp.arange(B)[:, None], idx].set(rows.astype(jnp.float32))

    qw, sw = quantize_kv_pages(win.reshape(B, NT, page, KH, D))
    pages = pages.at[pids.reshape(-1)].set(qw.reshape(-1, page, KH, D))
    scales = scales.at[pids.reshape(-1)].set(sw.reshape(-1, KH))
    return pages, scales
