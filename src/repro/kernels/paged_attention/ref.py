"""Pure-jnp oracle for paged attention decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array,
                        k_scales: jax.Array | None = None,
                        v_scales: jax.Array | None = None) -> jax.Array:
    """Decode attention over a paged KV pool.

    q:            (B, H, D)        one query token per sequence
    k/v_pages:    (P, page, KH, D) global page pool
    block_tables: (B, NP) int32    page ids per sequence (sequential fill)
    lengths:      (B,) int32       tokens in each sequence's KV
    k/v_scales:   (P, KH) f32      optional int8 per-page per-head scales
    returns:      (B, H, D)
    """
    B, H, D = q.shape
    P, page, KH, _ = k_pages.shape
    NP = block_tables.shape[1]
    G = H // KH

    k = k_pages[block_tables]            # (B, NP, page, KH, D)
    v = v_pages[block_tables]
    if k_scales is not None:
        from .quant import dequantize_kv_pages
        k = dequantize_kv_pages(k, k_scales[block_tables], q.dtype)
        v = dequantize_kv_pages(v, v_scales[block_tables], q.dtype)
    k = k.reshape(B, NP * page, KH, D)
    v = v.reshape(B, NP * page, KH, D)
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    pos = jnp.arange(NP * page)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, D).astype(q.dtype)
