from .kernel import flash_attention
from .ops import flash_attention_bshd, flash_attention_ref_bshd
from .ref import flash_attention_ref
