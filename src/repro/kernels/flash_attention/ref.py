"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B,H,Sq,D); k/v: (B,KH,Sk,D) with H = KH*G.  fp32 softmax."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, Sq, D).astype(q.dtype)
