"""Pallas TPU flash attention (prefill, causal/windowed, GQA).

Grid: (batch, q_head, num_q_blocks, num_kv_blocks); the kv axis is the
innermost (sequential on TPU), so the online-softmax accumulators live in
VMEM scratch and persist across kv steps.  BlockSpecs tile
  q: (1, 1, block_q, D)      k/v: (1, 1, block_kv, D)
with the kv-head index derived from the q-head index (GQA: h -> h // G).
The MXU sees (block_q x D) @ (D x block_kv) matmuls — block sizes default to
multiples of 128 to keep lanes full.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_kv: int, causal: bool,
            window: int, num_kv_blocks: int, seq_q: int, seq_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 1)
    mask = (qpos < seq_q) & (kpos < seq_kv)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    v = v_ref[0, 0].astype(jnp.float32)
    # zero the kv padding: OOB block reads are undefined (NaN in interpret
    # mode) and 0 * NaN would poison the accumulator
    kv_valid = (ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_kv, 1), 0)) < seq_kv
    v = jnp.where(kv_valid, v, 0.0)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,Sq,D); k/v: (B,KH,Sk,D) -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_kv)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window, num_kv_blocks=nk, seq_q=Sq, seq_kv=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running denom
            pltpu.VMEM((block_q, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
