"""Jit'd wrapper for the flash attention kernel, model-layout friendly."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_bshd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False) -> jax.Array:
    """Model layout (B,S,H,D)/(B,S,KH,D) -> (B,S,H,D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          block_q=block_q, block_kv=block_kv,
                          interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def flash_attention_ref_bshd(q, k, v, *, causal=True, window=0):
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    return jnp.swapaxes(
        flash_attention_ref(qt, kt, vt, causal=causal, window=window), 1, 2)
