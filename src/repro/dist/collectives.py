"""Compressed cross-shard reductions for slow heterogeneous links.

Helix clusters mix fast intra-node interconnects with slow inter-node
Ethernet; a full-precision all-reduce over the slow axis is the bandwidth
bottleneck for gradient sync and tensor-parallel partial sums.  Two
standard lossy schemes, both expressed with shard-local quantization plus
an ``all_gather`` of the compressed payload (4x fewer bytes than an fp32
ring all-reduce for int8; O(rank * (m + n)) instead of O(m * n) for
low-rank):

* ``int8``    — per-shard absmax int8 quantization; each shard dequantizes
                with the gathered per-shard scales and reduces locally.
* ``lowrank`` — PowerSGD-style rank-r projection: psum the projected
                matrix, orthonormalize, psum the back-projection.

Both are deterministic and SPMD-uniform (usable inside shard_map bodies).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8 quantization: x ~= q * scale."""
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # (W, ...) int8 payload
    scales = jax.lax.all_gather(scale, axis_name)  # (W,) fp32 sidecar
    deq = qs.astype(jnp.float32) * scales.reshape(
        (-1,) + (1,) * (qs.ndim - 1))
    return deq.sum(axis=0).astype(x.dtype)


def _lowrank_psum(x: jax.Array, axis_name: str, rank: int) -> jax.Array:
    m = x.reshape(x.shape[0], -1)
    r = max(1, min(rank, *m.shape))
    # shared deterministic test matrix (identical on every shard)
    q0 = jax.random.normal(jax.random.key(0), (m.shape[1], r), jnp.float32)
    p = jax.lax.psum(m.astype(jnp.float32) @ q0, axis_name)
    p_hat, _ = jnp.linalg.qr(p)                    # (m, r) orthonormal
    back = jax.lax.psum(m.astype(jnp.float32).T @ p_hat, axis_name)
    approx = p_hat @ back.T                        # P̂ P̂ᵀ Σᵢ Mᵢ
    return approx.reshape(x.shape).astype(x.dtype)


def compressed_psum(x: jax.Array, axis_name: str, *, method: str = "int8",
                    rank: int = 8) -> jax.Array:
    """Lossy ``lax.psum`` replacement over ``axis_name``.

    ``int8`` (default) keeps worst-case relative error well under 2% for
    zero-mean inputs; ``lowrank`` needs x.ndim >= 2 and trades accuracy
    for O(rank) bandwidth (use for gradient matrices with fast-decaying
    spectra).
    """
    if method == "int8":
        return _int8_psum(x, axis_name)
    if method == "lowrank":
        if x.ndim < 2:
            return _int8_psum(x, axis_name)
        return _lowrank_psum(x, axis_name, rank)
    raise ValueError(f"unknown compression method {method!r}")
