"""Helix-placement-driven pipeline parallelism.

The MILP planner (``repro.core``) assigns each heterogeneous node a
contiguous layer range; this module turns that placement into an *unequal*
GPipe pipeline executed with ``shard_map`` over a ``("stage", "data")``
mesh (HexGen-style asymmetric partitioning: a 4-chip slice gets a bigger
stage than a 1-chip slice).

Layout: the repeated super-block stack (``params["super"]``, leading
"layers" axis of length ``cfg.repeats``) is re-stacked to a
``(num_stages, max_units, ...)`` array sharded along the mesh "stage" axis.
Stages holding fewer than ``max_units`` super-blocks mask the padded scan
steps to identity, so the compiled program is SPMD-uniform while the math
follows the uneven placement exactly.

Schedule: classic GPipe fill/steady/drain — ``num_microbatches +
num_stages - 1`` ticks; each tick every stage applies its blocks to the
activation received from its predecessor (``lax.ppermute`` shift along
"stage"), stage 0 ingests a fresh microbatch, the last stage accumulates
masked token-level NLL sums.  The final loss psums the (nll, count)
accumulators over ("stage", "data") and divides, which reproduces the
single-program ``models.loss_fn`` to float tolerance and is differentiable
end to end (ppermute and the masked scans all have transposes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.placement import Placement
from ..models.common import ParamSpec, apply_norm


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    stage_units: Tuple[int, ...]     # super-blocks per stage (may be uneven)
    num_microbatches: int = 1

    def __post_init__(self):
        assert self.num_stages >= 1
        assert len(self.stage_units) == self.num_stages, \
            (self.stage_units, self.num_stages)
        assert all(u >= 0 for u in self.stage_units), self.stage_units
        assert self.num_microbatches >= 1

    @property
    def max_units(self) -> int:
        return max(self.stage_units)

    @property
    def total_units(self) -> int:
        return sum(self.stage_units)


# ---------------------------------------------------------------------------
# Helix placement -> stage sizes
# ---------------------------------------------------------------------------

def stage_units_from_placement(placement: Placement, cfg: ModelConfig,
                               order: Sequence[str]) -> List[int]:
    """Map a Helix placement's per-node layer ranges to per-stage
    super-block counts, in pipeline ``order``.

    The planner's layer axis may be expressed either in super-block units
    (``placement.num_layers == cfg.repeats``) or in raw model layers
    (``== len(cfg.pattern) * cfg.repeats``); both map to stage sizes in
    super-block units — the granularity the ``lax.scan`` pipeline executes.

    Replicated placements are reduced Helix-style (§3.3 partial inference):
    a node only contributes the layers not yet covered by earlier stages;
    nodes fully covered by their predecessors are dropped.  Gaps or splits
    that cut through a super-block raise ``ValueError``.
    """
    pat = max(1, len(cfg.pattern))
    total = placement.num_layers
    if cfg.prologue and total == cfg.num_layers:
        raise ValueError(
            "placements over prologue layers cannot be pipelined; plan over "
            f"the {cfg.repeats}-super-block repeated stack instead")
    if total == cfg.repeats:
        per_unit = 1
    elif total == cfg.repeats * pat:
        per_unit = pat
    else:
        raise ValueError(
            f"placement covers {total} layers; expected {cfg.repeats} "
            f"(super-block units) or {cfg.repeats * pat} (raw layers) "
            f"for {cfg.name}")
    units: List[int] = []
    cursor = 0
    for node in order:
        rng = placement.assignment[node]
        if rng.end <= cursor:
            continue  # fully covered by earlier stages (replicated node)
        start = max(rng.start, cursor)
        if start > cursor:
            raise ValueError(
                f"layer gap before {node}: covered up to {cursor}, "
                f"next range starts at {rng.start}")
        take = rng.end - cursor
        if take % per_unit:
            raise ValueError(
                f"{node}: stage boundary at layer {rng.end} cuts through a "
                f"{per_unit}-layer super-block")
        units.append(take // per_unit)
        cursor = rng.end
    if cursor != total:
        raise ValueError(f"placement covers layers [0, {cursor}) of {total}")
    return units


# ---------------------------------------------------------------------------
# Stage-stacked param specs
# ---------------------------------------------------------------------------

def pipeline_param_specs(cfg: ModelConfig, pipe: PipelineConfig) -> Dict:
    """ParamSpec tree for the pipelined model.

    Identical to ``models.param_specs`` except ``"super"`` leaves gain a
    leading ("stage", max_units) layout replacing the flat ("layers",)
    stack; entries past a stage's real unit count are padding (masked to
    identity at apply time).  Stage s holds super-blocks
    ``[sum(units[:s]), sum(units[:s+1]))`` of the flat stack.
    """
    if cfg.prologue or cfg.is_encoder_decoder:
        raise NotImplementedError(
            "pipeline parallelism covers the repeated super-block stack; "
            f"{cfg.name} has prologue/encoder blocks")
    from ..models import model as M
    base = M.param_specs(cfg)
    S, U = pipe.num_stages, pipe.max_units

    def restack(s: ParamSpec) -> ParamSpec:
        # base "super" leaves are ("layers",)+axes with shape (repeats, ...)
        return ParamSpec((S, U) + s.shape[1:], ("stage",) + s.axes,
                         init=s.init, scale=s.scale)

    out = dict(base)
    out["super"] = jax.tree.map(restack, base["super"],
                                is_leaf=lambda x: isinstance(x, ParamSpec))
    return out


def flatten_pipeline_params(params, pipe: PipelineConfig):
    """Inverse of the stage stacking: (S, U, ...) pipeline "super" leaves ->
    the single-program (repeats, ...) layer stack (drops padding)."""
    def unstack(x):
        parts = [x[s, :u] for s, u in enumerate(pipe.stage_units) if u]
        return jnp.concatenate(parts, axis=0)
    out = dict(params)
    out["super"] = jax.tree.map(unstack, params["super"])
    return out


# ---------------------------------------------------------------------------
# Pipelined loss
# ---------------------------------------------------------------------------

def _ce_sums(logits: jax.Array, labels: jax.Array):
    """(sum of NLL over valid tokens, valid-token count) — the same masked
    cross-entropy as models.loss_fn, pre-normalization."""
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.where(valid, nll, 0.0).sum(), valid.sum().astype(jnp.float32)


def make_pipeline_loss(cfg: ModelConfig, pipe: PipelineConfig, mesh: Mesh,
                       *, aux_weight: float = 0.0):
    """Jitted ``loss(params, batch) -> scalar`` running the GPipe schedule
    over mesh axes ("stage", "data").

    ``params`` comes from ``pipeline_param_specs``; ``batch`` holds
    ``tokens``/``labels`` of shape (B, S) with B divisible by
    ``data_size * num_microbatches``.  Matches ``models.loss_fn(...,
    aux_weight=0.0)`` exactly up to float reassociation; MoE aux losses are
    averaged over microbatches (a per-microbatch approximation of the
    full-batch load-balance term).
    """
    from ..models import model as M
    assert "stage" in mesh.axis_names and "data" in mesh.axis_names, \
        mesh.axis_names
    S = pipe.num_stages
    n_mb = pipe.num_microbatches
    U = pipe.max_units
    units_arr = jnp.asarray(pipe.stage_units, jnp.int32)
    if pipe.total_units != cfg.repeats:
        raise ValueError(f"stage_units {pipe.stage_units} sum to "
                         f"{pipe.total_units}; {cfg.name} has "
                         f"{cfg.repeats} super-blocks")

    def stage_apply(sup, h, positions, my_units):
        """Apply this stage's super-blocks; padded units are identity."""
        def unit(carry, xs):
            h, aux_acc = carry
            u, layer_params = xs
            hn, aux_u = h, jnp.zeros((), jnp.float32)
            for i, b in enumerate(cfg.pattern):
                hn, _, a = M._apply_block(cfg, b, layer_params[f"pos{i}"],
                                          hn, positions, None)
                aux_u = aux_u + a
            keep = u < my_units
            return (jnp.where(keep, hn, h),
                    aux_acc + jnp.where(keep, aux_u, 0.0)), None
        (h, aux), _ = jax.lax.scan(unit, (h, jnp.zeros((), jnp.float32)),
                                   (jnp.arange(U), sup))
        return h, aux

    def shard_body(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        sup = jax.tree.map(lambda x: x[0], params["super"])  # drop stage dim
        stage = jax.lax.axis_index("stage")
        my_units = units_arr[stage]
        B_loc, S_seq = tokens.shape
        if B_loc % n_mb:
            raise ValueError(
                f"per-data-shard batch {B_loc} not divisible by "
                f"{n_mb} microbatches")
        mb = B_loc // n_mb
        tok_mb = tokens.reshape(n_mb, mb, S_seq)
        lab_mb = labels.reshape(n_mb, mb, S_seq)
        positions = jnp.broadcast_to(jnp.arange(S_seq), (mb, S_seq))
        is_first = stage == 0
        is_last = stage == S - 1
        state0 = jnp.zeros((mb, S_seq, cfg.d_model), params["embed"].dtype)
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            state, nll_sum, valid_sum, aux_sum = carry
            # stage 0 ingests microbatch t; others consume the shifted state
            t_in = jnp.clip(t, 0, n_mb - 1)
            tok = jax.lax.dynamic_index_in_dim(tok_mb, t_in, 0,
                                               keepdims=False)
            emb = jnp.take(params["embed"], tok, axis=0)
            x = jnp.where(is_first, emb, state)
            h, aux = stage_apply(sup, x, positions, my_units)
            # this stage processed microbatch t - stage (if in range)
            live = jnp.logical_and(t - stage >= 0, t - stage < n_mb)
            aux_sum = aux_sum + jnp.where(live, aux, 0.0)
            # last stage: microbatch t - (S-1) just finished.  The vocab
            # projection + CE only run on live last-stage ticks (lax.cond),
            # not in every stage's bubble ticks
            t_out = t - (S - 1)
            done = jnp.logical_and(
                is_last, jnp.logical_and(t_out >= 0, t_out < n_mb))
            lab = jax.lax.dynamic_index_in_dim(
                lab_mb, jnp.clip(t_out, 0, n_mb - 1), 0, keepdims=False)

            def ce(operand):
                h, lab = operand
                hn = apply_norm(cfg, params["final_norm"], h)
                return _ce_sums(M._logits(cfg, params, hn), lab)

            zero = jnp.zeros((), jnp.float32)
            nll, cnt = jax.lax.cond(done, ce, lambda _: (zero, zero),
                                    (h, lab))
            nll_sum = nll_sum + nll
            valid_sum = valid_sum + cnt
            state = jax.lax.ppermute(h, "stage", perm) if perm else h
            return (state, nll_sum, valid_sum, aux_sum), None

        zero = jnp.zeros((), jnp.float32)
        (_, nll_sum, valid_sum, aux_sum), _ = jax.lax.scan(
            tick, (state0, zero, zero, zero),
            jnp.arange(n_mb + S - 1))
        nll = jax.lax.psum(nll_sum, ("stage", "data"))
        valid = jax.lax.psum(valid_sum, ("stage", "data"))
        aux = jax.lax.psum(aux_sum, ("stage", "data")) / n_mb
        return nll / jnp.maximum(valid, 1.0) + aux_weight * aux

    specs = pipeline_param_specs(cfg, pipe)

    def leaf_spec(s: ParamSpec) -> P:
        if s.axes and s.axes[0] == "stage":
            return P(*(("stage",) + (None,) * (len(s.shape) - 1)))
        return P(*((None,) * len(s.shape)))

    pspecs = jax.tree.map(leaf_spec, specs,
                          is_leaf=lambda x: isinstance(x, ParamSpec))
    bspec = P("data", None)
    fn = shard_map(shard_body, mesh=mesh,
                   in_specs=(pspecs, {"tokens": bspec, "labels": bspec}),
                   out_specs=P(), check_rep=False)
    return jax.jit(fn)
