"""Distribution layer: logical-axis sharding rules, Helix-placement-driven
pipeline parallelism, and compressed collectives.

See README.md in this directory for the logical-axis vocabulary and the
rule tables.
"""
from .collectives import compressed_psum, dequantize_int8, quantize_int8
from .sharding import (LONG_CONTEXT_RULES, SERVE_RULES, TRAIN_RULES,
                       ShardingRules, moe_variant, opt_state_shardings,
                       sharding_for, tree_shardings)
from .pipeline import (PipelineConfig, flatten_pipeline_params,
                       make_pipeline_loss, pipeline_param_specs,
                       stage_units_from_placement)

__all__ = [
    "compressed_psum", "quantize_int8", "dequantize_int8",
    "ShardingRules", "TRAIN_RULES", "SERVE_RULES", "LONG_CONTEXT_RULES",
    "moe_variant", "sharding_for", "tree_shardings", "opt_state_shardings",
    "PipelineConfig", "make_pipeline_loss", "pipeline_param_specs",
    "stage_units_from_placement", "flatten_pipeline_params",
]
