"""Logical-axis sharding rules: the single translation point between model
code (which names tensor dims with the logical vocabulary in
``models/common.py``) and a concrete device mesh.

A ``ShardingRules`` table maps each logical axis name to a mesh axis (or a
tuple of mesh axes, or ``None`` for replicated).  ``spec`` applies a table to
one tensor, enforcing the two invariants the rest of the stack relies on:

* **divisibility fallback** — a dim that a mesh axis does not divide evenly
  is replicated instead of erroring, so smoke configs (15 heads, 30-dim
  embeds) run on any mesh;
* **no duplicate mesh axes** — each mesh axis is assigned at most once per
  tensor, first (leftmost) logical axis wins, later claims replicate.

Mesh axes named in a rule but absent from the mesh are skipped (a
``("pod", "data")`` batch rule degrades gracefully on a 2-axis mesh).

Tables:
  TRAIN_RULES        FSDP over "data" (params shard their embed dim) + TP
                     over "model" (heads/ff/experts/vocab).
  SERVE_RULES        pure TP: params replicated across "data" (each data
                     replica serves its own batch shard), KV caches shard
                     batch over "data" and kv_heads over "model".
  LONG_CONTEXT_RULES batch=1 sequence parallelism: KV caches shard their
                     sequence dim over "model", weights shard over
                     "pod"/"data" instead.
  moe_variant(base)  expert parallelism: experts spread over the full
                     ("data", "model") mesh, expert-local dims replicate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Ordered (logical axis -> mesh axes) table."""

    rules: Tuple[Tuple[str, MeshAxes], ...]

    def lookup(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        for rule_name, mesh_axes in self.rules:
            if rule_name == name:
                return mesh_axes
        return None

    def spec(self, axes: Sequence[Optional[str]], mesh: Mesh,
             shape: Sequence[int]) -> P:
        """PartitionSpec for one tensor of ``shape`` with logical ``axes``.

        Applies divisibility fallback and the no-duplicate-mesh-axis
        invariant; trailing replicated dims are stripped so fully-replicated
        tensors get the canonical ``P()``.
        """
        assert len(axes) == len(shape), (axes, shape)
        sizes = dict(zip(mesh.axis_names, mesh.shape.values())) \
            if hasattr(mesh.shape, "values") else dict(
                zip(mesh.axis_names, mesh.devices.shape))
        used: set = set()
        entries: list = []
        for dim, name in zip(shape, axes):
            mapped = self.lookup(name)
            if mapped is None:
                entries.append(None)
                continue
            cand = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            # skip mesh axes this mesh does not have at all
            cand = tuple(a for a in cand if a in sizes)
            if not cand or any(a in used for a in cand):
                entries.append(None)
                continue
            total = 1
            for a in cand:
                total *= sizes[a]
            if total <= 0 or dim % total != 0:
                entries.append(None)
                continue
            used.update(cand)
            entries.append(cand[0] if len(cand) == 1 else cand)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)


def sharding_for(shape: Sequence[int], axes: Sequence[Optional[str]],
                 rules: ShardingRules, mesh: Mesh) -> NamedSharding:
    """NamedSharding for one tensor (see ``ShardingRules.spec``)."""
    return NamedSharding(mesh, rules.spec(tuple(axes), mesh, tuple(shape)))


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

TRAIN_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data")),
    ("seq", None),
    ("embed", "data"),          # FSDP: param embed dims shard over data
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", "model"),
    ("ff", "model"),
    ("experts", "model"),
    ("vocab", "model"),
    ("layers", None),           # scan axis stays on-device
    ("stage", "stage"),         # pipeline stage axis (dist.pipeline meshes)
    ("state", None),
    ("conv", None),
    ("lora", None),
))

SERVE_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data")),
    ("seq", None),
    ("embed", None),            # params replicated across data replicas
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", "model"),
    ("ff", "model"),
    ("experts", "model"),
    ("vocab", "model"),
    ("layers", None),
    ("stage", "stage"),
    ("state", None),
    ("conv", None),
    ("lora", "model"),
))

LONG_CONTEXT_RULES = ShardingRules(rules=(
    ("batch", None),            # long-context decode is batch=1
    ("seq", "model"),           # KV cache shards along sequence
    ("embed", None),
    ("heads", ("pod", "data")),
    ("kv_heads", ("pod", "data")),
    ("head_dim", None),
    ("ff", ("pod", "data")),
    ("experts", ("pod", "data")),
    ("vocab", ("pod", "data")),
    ("layers", None),
    ("stage", "stage"),
    ("state", None),
    ("conv", None),
    ("lora", None),
))


def moe_variant(base: ShardingRules) -> ShardingRules:
    """Expert-parallel variant: experts spread over the whole (data, model)
    mesh so each device holds E / (data*model) experts; per-expert dims
    (already claimed mesh axes) replicate via the duplicate-axis rule."""
    return ShardingRules(rules=tuple(
        (name, ("data", "model")) if name == "experts" else (name, ax)
        for name, ax in base.rules))


# ---------------------------------------------------------------------------
# Tree helpers (used by launch.steps cell building and the train/serve
# drivers to turn ParamSpec logical axes into jit in/out shardings)
# ---------------------------------------------------------------------------

def tree_shardings(shapes, axes, rules: ShardingRules, mesh: Mesh):
    """Map matching (shape-tree, logical-axes-tree) to NamedShardings."""
    return jax.tree.map(
        lambda s, ax: sharding_for(tuple(s.shape), tuple(ax), rules, mesh),
        shapes, axes,
        is_leaf=lambda x: hasattr(x, "shape"))


def opt_state_shardings(opt_cfg, params_abs, params_axes, params_sh,
                        rules: ShardingRules, mesh: Mesh):
    """Optimizer-state shardings derived from param logical axes.

    AdamW m/v mirror the params; Adafactor's factored second moments drop
    the last (vr) / second-to-last (vc) dims and inherit the remaining axes.
    """
    from ..training.optimizer import _factored
    rep = NamedSharding(mesh, P())
    if opt_cfg.name == "adamw":
        return {"m": params_sh, "v": params_sh, "step": rep}
    flat_p = jax.tree.leaves(params_abs)
    flat_ax = jax.tree.structure(params_abs).flatten_up_to(params_axes)
    v = []
    for p, ax in zip(flat_p, flat_ax):
        ax = tuple(ax)
        if _factored(p.shape, opt_cfg.min_dim_factored):
            v.append({
                "vr": sharding_for(p.shape[:-1], ax[:-1], rules, mesh),
                "vc": sharding_for(p.shape[:-2] + p.shape[-1:],
                                   ax[:-2] + ax[-1:], rules, mesh),
            })
        else:
            v.append({"v": sharding_for(p.shape, ax, rules, mesh)})
    return {"v": v, "step": rep}
