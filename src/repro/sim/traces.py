"""Request traces for the simulator (paper §5.2, Azure Conversation-like).

The paper prunes the Azure Conversation dataset to input <= 2048 and output
<= 1024, yielding 16657 requests with mean input 763 and mean output 232.
We generate a synthetic trace matched to those statistics (lognormal lengths
clipped to the caps), plus Poisson/online arrival processes scaled to a
fraction of cluster peak throughput.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Iterator, List, Optional


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    request_id: int
    arrival_s: float
    input_tokens: int
    output_tokens: int


def _lognormal_clipped(rng: random.Random, mean_target: float, cap: int,
                       sigma: float) -> int:
    # pick mu so the clipped mean approximates mean_target (sigma fixed)
    mu = math.log(mean_target) - sigma ** 2 / 2
    x = rng.lognormvariate(mu, sigma)
    return max(1, min(cap, int(x)))


def azure_conversation_lengths(rng: random.Random) -> tuple:
    """Input/output lengths matched to the pruned Azure Conversation stats
    (mean input 763 <= 2048, mean output 232 <= 1024)."""
    inp = _lognormal_clipped(rng, mean_target=820.0, cap=2048, sigma=0.9)
    out = _lognormal_clipped(rng, mean_target=250.0, cap=1024, sigma=0.8)
    return inp, out


def _poisson_gap(rng: random.Random, rate_per_s: float,
                 burstiness: float) -> float:
    """One inter-arrival gap of the (optionally bursty) Poisson process.
    ``burstiness`` in [0,1) mixes in a second, 4x-rate regime to mimic
    the diurnal bursts of the real trace."""
    rate = rate_per_s
    if burstiness and rng.random() < burstiness:
        rate *= 4.0
    return rng.expovariate(rate)


def arrival_gaps(rate_per_s: float, *, seed: int = 0,
                 burstiness: float = 0.0) -> Iterator[float]:
    """Endless inter-arrival gaps for an open-loop arrival process — the
    SAME process ``make_trace`` uses for the simulator, shared with the
    wall-clock client (``examples/openloop_client.py``) and the online
    latency benchmark so simulated and served arrivals agree."""
    rng = random.Random(seed)
    while True:
        yield _poisson_gap(rng, rate_per_s, burstiness)


def arrival_times(n: int, rate_per_s: float, *, seed: int = 0,
                  burstiness: float = 0.0) -> List[float]:
    """First ``n`` absolute arrival times of the open-loop process."""
    gaps = arrival_gaps(rate_per_s, seed=seed, burstiness=burstiness)
    t, out = 0.0, []
    for _ in range(n):
        t += next(gaps)
        out.append(t)
    return out


def make_trace(num_requests: int, arrival_rate_per_s: float,
               seed: int = 0, burstiness: float = 0.0) -> List[TraceRequest]:
    """Poisson arrivals at ``arrival_rate_per_s`` requests/s (see
    ``arrival_gaps`` for the burstiness mix)."""
    rng = random.Random(seed)
    out: List[TraceRequest] = []
    t = 0.0
    for i in range(num_requests):
        t += _poisson_gap(rng, arrival_rate_per_s, burstiness)
        inp, outp = azure_conversation_lengths(rng)
        out.append(TraceRequest(i, t, inp, outp))
    return out


def make_offline_trace(num_requests: int, seed: int = 0) -> List[TraceRequest]:
    """Offline serving: all requests available at t=0 (rate-unconstrained)."""
    rng = random.Random(seed)
    out = []
    for i in range(num_requests):
        inp, outp = azure_conversation_lengths(rng)
        out.append(TraceRequest(i, 0.0, inp, outp))
    return out


def online_rate_for_cluster(peak_decode_tokens_per_s: float,
                            utilization: float = 0.75,
                            mean_output_tokens: float = 250.0) -> float:
    """Paper: online arrivals scaled to 75% of the cluster's peak throughput."""
    return peak_decode_tokens_per_s * utilization / mean_output_tokens
