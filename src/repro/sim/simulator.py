"""Event-driven simulator for distributed LLM serving on heterogeneous
clusters (paper §5.1 "Simulator").

Entities:
  * NodeSim  — a compute node: FIFO batch server at the profiled token rate,
    with a KV-cache occupancy model (prompt reserves, decode grows, overshoot
    triggers an offload penalty) mirroring vLLM-style paging behaviour.
  * LinkSim  — a directed network link: serialization at bandwidth + fixed
    propagation latency; FIFO queueing captures congestion (the paper's §5.7
    case study).
  * Simulator — drives request lifecycles: arrival → per-request pipeline
    from a scheduler → prompt pass through stages → autoregressive decode
    passes (chunked by ``decode_chunk`` for speed) → completion.

Pipelined decode mirrors the ClusterRuntime's in-flight window: each pass
is its own ``_Pass`` walking the stages, and with ``max_inflight`` >= 2 the
final stage launches the next chunk straight back to stage 0 while the
produced tokens travel to the coordinator — so the simulator and the real
runtime model the same overlap and stay comparable.  ``max_inflight=1``
(default) reproduces the classic one-outstanding-pass walk exactly.

Speculative decoding mirrors the runtime's draft-model path: with
``spec_tokens`` > 0 each decode pass verifies a window of draft tokens and
confirms the expected accepted prefix (``spec_acceptance`` per-token), so
tokens-per-round-trip scales with draft quality while every stage still
computes — and every link still carries — the full window.

Fault-tolerance hooks: ``fail_node(t, name)`` kills a node mid-run (in-flight
requests restart on a replanned placement), ``slow_node(t, name, factor)``
injects a straggler; both exercise the planner's elastic replanning.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple

from ..core.cluster import COORDINATOR, ClusterSpec, ModelProfile
from ..core.placement import Placement
from ..core.scheduler import BaseScheduler, RequestPipeline
from .traces import TraceRequest


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Metrics:
    warmup_s: float
    horizon_s: float
    decoded_tokens: int = 0
    prompt_tokens: int = 0
    completed_requests: int = 0
    prompt_latencies: List[float] = dataclasses.field(default_factory=list)
    decode_latencies: List[float] = dataclasses.field(default_factory=list)
    node_busy_s: Dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    link_queue_s: Dict[Tuple[str, str], float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    link_transfers: Dict[Tuple[str, str], int] = dataclasses.field(default_factory=lambda: defaultdict(int))
    link_bytes: Dict[Tuple[str, str], float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    restarts: int = 0
    dropped_requests: int = 0
    # client-cancelled requests (the ``cancel`` hook — parity with
    # ``ClusterRuntime.cancelled_requests``)
    cancelled_requests: int = 0
    # cluster rental price and scale/fault decisions taken during the run
    # (parity with the live Autoscaler's event log)
    cost_per_hour: float = 0.0
    autoscale_events: List[Tuple[float, str, str]] = dataclasses.field(
        default_factory=list)
    # speculative decoding (mirrors ClusterRuntime's counters): drafts
    # proposed / accepted / rejected and verify round-trips completed
    spec_proposed: int = 0
    spec_accepted: int = 0
    spec_rejected: int = 0
    spec_rounds: int = 0
    spec_confirmed: int = 0

    @property
    def spec_acceptance_rate(self) -> float:
        return self.spec_accepted / max(1, self.spec_proposed)

    @property
    def spec_tokens_per_round_trip(self) -> float:
        return self.spec_confirmed / max(1, self.spec_rounds)

    @property
    def measure_window_s(self) -> float:
        return max(1e-9, self.horizon_s - self.warmup_s)

    @property
    def decode_throughput(self) -> float:
        return self.decoded_tokens / self.measure_window_s

    @property
    def processed_throughput(self) -> float:
        """Prompt + decode tokens per second — comparable to the max-flow
        bound, which counts every token passing through the cluster."""
        return (self.decoded_tokens + self.prompt_tokens) / self.measure_window_s

    @property
    def dollars_per_million_tokens(self) -> float:
        """Serving cost at the measured throughput — the mix planner's
        objective expressed per token instead of per hour."""
        tput = self.processed_throughput
        if tput <= 0:
            return float("inf")
        return (self.cost_per_hour / 3600.0) / tput * 1e6

    def _stats(self, xs: List[float]) -> Dict[str, float]:
        if not xs:
            return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        s = sorted(xs)
        pick = lambda q: s[min(len(s) - 1, int(q * len(s)))]
        return {"mean": sum(s) / len(s), "p50": pick(0.5), "p90": pick(0.9),
                "p99": pick(0.99)}

    @property
    def prompt_latency(self) -> Dict[str, float]:
        return self._stats(self.prompt_latencies)

    @property
    def decode_latency(self) -> Dict[str, float]:
        return self._stats(self.decode_latencies)

    def node_utilization(self, horizon: Optional[float] = None) -> Dict[str, float]:
        h = horizon or self.horizon_s
        return {n: b / max(h, 1e-9) for n, b in sorted(self.node_busy_s.items())}


# ---------------------------------------------------------------------------
# Servers
# ---------------------------------------------------------------------------

class NodeSim:
    def __init__(self, name: str, rate_tokens_per_s: float,
                 kv_capacity_tokens: float, batch_token_cap: float = 4096,
                 batch_overhead_s: float = 0.015,
                 offload_penalty: float = 0.25):
        self.name = name
        self.rate = rate_tokens_per_s
        self.kv_capacity = kv_capacity_tokens
        self.kv_used = 0.0
        self.batch_token_cap = batch_token_cap
        self.batch_overhead_s = batch_overhead_s
        self.offload_penalty = offload_penalty
        self.pending: deque = deque()   # (work_units, done_cb, pass)
        self.kv_wait: deque = deque()   # (work_units, kv_need, kv_grow,
                                        #  done_cb, pass)
        self.busy_until = 0.0
        self.alive = True
        self.speed_factor = 1.0

    def effective_rate(self) -> float:
        rate = self.rate * self.speed_factor
        if self.kv_capacity > 0 and self.kv_used > self.kv_capacity:
            rate *= self.offload_penalty  # paging to host memory
        return max(rate, 1e-6)


class LinkSim:
    def __init__(self, src: str, dst: str, bandwidth: float, latency: float):
        self.src = src
        self.dst = dst
        self.bandwidth = bandwidth
        self.latency = latency
        self.busy_until = 0.0


# ---------------------------------------------------------------------------
# Request state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _ReqState:
    trace: TraceRequest
    pipeline: RequestPipeline
    arrival_s: float
    decoded: int = 0                 # output tokens confirmed at coordinator
    launched: int = 0                # output tokens covered by passes so far
    inflight: int = 0                # passes launched, not yet confirmed
    in_pipeline: bool = False        # a pass is inside the stages right now
    epoch: int = 0                   # bumped on restart: stale passes die
    first_token_s: Optional[float] = None
    restarted: int = 0
    # disaggregated prefill/decode: the prompt pass walks this pipeline
    # (decode walks ``pipeline``) and the first decode launch waits for
    # ``kv_handoffs`` prefill->decode KV transfers to land
    prefill_pipeline: Optional[RequestPipeline] = None
    prefill_scheduler: Optional[BaseScheduler] = None
    kv_handoffs: int = 0
    kv_need: float = 0.0             # prompt-time KV reservation per node
    # the scheduler that reserved this request's pipeline — reservations
    # must be released on the same estimator even after a replan swap
    scheduler: Optional[BaseScheduler] = None
    # exact KV charged per node so far — released verbatim on completion or
    # restart, so accounting can never drift from the charges
    kv_charged: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Pass:
    """One pipeline pass (the prompt, or one decode chunk) in flight.  With
    ``max_inflight`` >= 2 several passes of one request walk the stages
    concurrently, each carrying its own stage cursor."""
    state: _ReqState
    chunk: int                       # output tokens this pass produces
    start: int                       # output-token offset the chunk covers
    stage_idx: int = 0
    is_prompt: bool = False
    epoch: int = 0
    drafts: int = 0                  # speculative: draft tokens verified
                                     # alongside the confirmed input token


class Simulator:
    def __init__(self, cluster: ClusterSpec, model: ModelProfile,
                 placement: Placement, scheduler: BaseScheduler,
                 *, decode_chunk: int = 4, warmup_s: float = 30.0,
                 horizon_s: float = 600.0, batch_overhead_s: float = 0.015,
                 kv_output_estimate: int = 256,
                 replan_fn: Optional[Callable] = None,
                 max_decode_tokens: Optional[int] = None,
                 max_inflight: int = 1,
                 direct_links: bool = True,
                 prefill_scheduler: Optional[BaseScheduler] = None,
                 spec_tokens: int = 0,
                 spec_acceptance: float = 1.0):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if spec_tokens < 0:
            raise ValueError(f"spec_tokens must be >= 0, got {spec_tokens}")
        if not 0.0 <= spec_acceptance <= 1.0:
            raise ValueError(f"spec_acceptance must be in [0, 1], "
                             f"got {spec_acceptance}")
        self.max_inflight = max_inflight
        # speculative decoding: each decode pass verifies ``spec_tokens``
        # draft tokens alongside the confirmed input token, confirming the
        # expected accepted prefix 1 + sum(acceptance^i) per round-trip.
        # The pass still computes (and ships activations for) the FULL
        # 1 + spec_tokens window — rejected work is the cost of drafting
        self.spec_tokens = spec_tokens
        self.spec_acceptance = spec_acceptance
        # direct_links mirrors the runtime transports: True charges
        # stage->stage traffic on the (src, dst) link; False models the
        # coordinator-star dataflow (src->coordinator then coordinator->dst)
        self.direct_links = direct_links
        # a distinct prefill_scheduler turns on disaggregated mode: prompt
        # passes walk its pipelines, decode walks ``scheduler``'s, and the
        # KV handoff transfer gates the first decode launch
        self.prefill_scheduler = prefill_scheduler
        self.cluster = cluster
        self.model = model
        self.placement = placement
        self.scheduler = scheduler
        self.decode_chunk = decode_chunk
        self.warmup_s = warmup_s
        self.horizon_s = horizon_s
        self.kv_output_estimate = kv_output_estimate
        self.replan_fn = replan_fn
        self.max_decode_tokens = max_decode_tokens
        self.max_schedule_attempts = 20   # 10 s of 0.5 s retries, then drop

        self.nodes: Dict[str, NodeSim] = {}
        for name, rng in placement.assignment.items():
            rate = cluster.node_token_throughput(name, model, rng.num_layers)
            vram = cluster.nodes[name].vram_bytes
            free = max(0.0, vram - rng.num_layers * model.layer_param_bytes)
            # kv_bytes_per_token_layer carries the KV storage dtype: a
            # profile built with kv_dtype="int8" (1-byte pages + amortized
            # absmax scales) roughly doubles every node's token capacity
            # here, matching what serving.kv_pool.pages_for_vram gives the
            # real engines
            per_tok = model.kv_bytes_per_token_layer * rng.num_layers
            kv_cap = free / per_tok if per_tok > 0 else float("inf")
            self.nodes[name] = NodeSim(name, rate, kv_cap,
                                       batch_overhead_s=batch_overhead_s)
        self.links: Dict[Tuple[str, str], LinkSim] = {}
        for (src, dst), spec in cluster.links.items():
            self.links[(src, dst)] = LinkSim(src, dst,
                                             spec.bandwidth_bytes_per_s,
                                             spec.latency_s)

        self.metrics = Metrics(warmup_s=warmup_s, horizon_s=horizon_s,
                               cost_per_hour=cluster.cost_per_hour())
        self._events: List = []
        self._seq = 0
        self._now = 0.0
        self._live: Dict[int, "_ReqState"] = {}  # request_id -> state

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, fn: Callable, *args) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, fn, args))

    # -- network ------------------------------------------------------------
    def _transfer(self, src: str, dst: str, nbytes: float,
                  deliver: Callable) -> None:
        link = self.links.get((src, dst))
        if link is None:  # same node / missing link: instant
            self._push(self._now, deliver)
            return
        start = max(self._now, link.busy_until)
        queue_delay = start - self._now
        ser = nbytes / link.bandwidth
        link.busy_until = start + ser
        if self._now >= self.warmup_s:
            self.metrics.link_queue_s[(src, dst)] += queue_delay
            self.metrics.link_transfers[(src, dst)] += 1
            self.metrics.link_bytes[(src, dst)] += nbytes
        self._push(link.busy_until + link.latency, deliver)

    def _route_transfer(self, src: str, dst: str, nbytes: float,
                        deliver: Callable) -> None:
        """Node-to-node traffic takes the direct link when direct links
        are on; otherwise it bounces through the coordinator (two
        transfers, both charged), matching ``SocketTransport``'s star
        dataflow."""
        if self.direct_links or COORDINATOR in (src, dst) or src == dst:
            self._transfer(src, dst, nbytes, deliver)
            return
        self._transfer(src, COORDINATOR, nbytes,
                       lambda: self._transfer(COORDINATOR, dst, nbytes,
                                              deliver))

    # -- node batch server ----------------------------------------------------
    def _charge_kv(self, ns: NodeSim, state: "_ReqState",
                   amount: float) -> None:
        if amount > 0:
            ns.kv_used += amount
            state.kv_charged[ns.name] = \
                state.kv_charged.get(ns.name, 0.0) + amount

    def _release_kv(self, state: "_ReqState") -> None:
        """Return every byte-token this request charged, exactly — then wake
        kv-waiters on those nodes.  Without the wakeup, a request whose
        completion freed the capacity a waiter needs would strand it forever
        when no other batch ever lands on that node."""
        touched = list(state.kv_charged)
        for node, amt in state.kv_charged.items():
            ns = self.nodes.get(node)
            if ns is not None:
                ns.kv_used = max(0.0, ns.kv_used - amt)
        state.kv_charged.clear()
        for node in touched:
            self._admit_waiters(node)

    def _admit_waiters(self, node: str) -> None:
        """Admit kv-waiters (front-of-queue order) whose reservation now
        fits, dropping waiters whose request restarted while queued —
        charging those would leak KV the restart's release already cleared."""
        ns = self.nodes.get(node)
        if ns is None or not ns.alive:
            return
        while ns.kv_wait:
            w, need, grow, cb, p = ns.kv_wait[0]
            if p.epoch != p.state.epoch:
                ns.kv_wait.popleft()
                continue
            if ns.kv_used + need > ns.kv_capacity:
                break
            ns.kv_wait.popleft()
            self._charge_kv(ns, p.state, need + grow)
            ns.pending.append((w, cb, p))
        self._kick(node)

    def _enqueue_work(self, node: str, work_units: float, kv_need: float,
                      kv_grow: float, done: Callable, p: "_Pass") -> None:
        ns = self.nodes[node]
        if not ns.alive:
            self._restart_pass(p)
            return
        if kv_need > 0 and ns.kv_used + kv_need > ns.kv_capacity:
            ns.kv_wait.append((work_units, kv_need, kv_grow, done, p))
            return
        self._charge_kv(ns, p.state, kv_need + kv_grow)
        ns.pending.append((work_units, done, p))
        self._kick(node)

    def _kick(self, node: str) -> None:
        ns = self.nodes[node]
        if not ns.alive or not ns.pending or ns.busy_until > self._now:
            return
        batch, tokens = [], 0.0
        while ns.pending and tokens < ns.batch_token_cap:
            w, cb, st = ns.pending.popleft()
            batch.append((cb, st))
            tokens += w
        dur = tokens / ns.effective_rate() + ns.batch_overhead_s
        ns.busy_until = self._now + dur
        if self._now >= self.warmup_s:
            self.metrics.node_busy_s[node] += dur
        self._push(ns.busy_until, self._batch_done, node, batch)

    def _batch_done(self, node: str, batch: List[Tuple]) -> None:
        ns = self.nodes[node]
        if not ns.alive:
            # node died while this batch was in flight: the work is lost,
            # restart the requests instead of stranding their reservations
            for _, p in batch:
                self._restart_pass(p)
            return
        for cb, _ in batch:
            cb()
        self._admit_waiters(node)

    # -- request lifecycle ----------------------------------------------------
    def _arrive(self, req: TraceRequest, restarted: int = 0,
                attempts: int = 0) -> None:
        amount = req.input_tokens + self.kv_output_estimate
        try:
            pipeline = self.scheduler.schedule(prompt_tokens=amount)
        except RuntimeError:
            # no route available (e.g. mid-replan): retry shortly, but cap
            # like _restart does instead of retrying every 0.5 s forever
            if attempts >= self.max_schedule_attempts:
                self.metrics.dropped_requests += 1
                return
            self._push(self._now + 0.5, self._arrive, req, restarted,
                       attempts + 1)
            return
        prefill_pipe = None
        if self.prefill_scheduler is not None:
            try:
                prefill_pipe = self.prefill_scheduler.schedule(
                    prompt_tokens=amount)
            except RuntimeError:
                self.scheduler.finish(pipeline, amount)
                if attempts >= self.max_schedule_attempts:
                    self.metrics.dropped_requests += 1
                    return
                self._push(self._now + 0.5, self._arrive, req, restarted,
                           attempts + 1)
                return
        state = _ReqState(trace=req, pipeline=pipeline, arrival_s=self._now,
                          restarted=restarted, scheduler=self.scheduler,
                          prefill_pipeline=prefill_pipe,
                          prefill_scheduler=(self.prefill_scheduler
                                             if prefill_pipe else None))
        self._live[req.request_id] = state
        # the prompt pass produces (and therefore "launches") the first
        # output token
        state.launched = 1
        state.inflight = 1
        state.in_pipeline = True
        p = _Pass(state, chunk=1, start=0, is_prompt=True, epoch=state.epoch)
        # coordinator -> first stage: token ids
        nbytes = req.input_tokens * self.model.token_bytes
        first = (prefill_pipe or pipeline).stages[0].node
        self._transfer(COORDINATOR, first, nbytes,
                       lambda: self._stage_work(p))

    def _limit(self, state: _ReqState) -> int:
        limit = state.trace.output_tokens
        if self.max_decode_tokens is not None:
            limit = min(limit, self.max_decode_tokens)
        return limit

    def _spec_chunk(self, remaining: int) -> Tuple[int, int]:
        """(expected confirmed tokens, draft count) for one verify pass with
        ``remaining`` output tokens still uncovered.  The accepted-prefix
        length under i.i.d. per-token acceptance ``a`` has expectation
        sum(a^i, i=1..gamma); plus one token the verify pass always
        confirms (the corrected/bonus token)."""
        gamma = max(0, min(self.spec_tokens, remaining - 1))
        expected, run = 1.0, 1.0
        for _ in range(gamma):
            run *= self.spec_acceptance
            expected += run
        return max(1, min(remaining, int(round(expected)))), gamma

    def _pass_tokens(self, p: _Pass) -> int:
        """Tokens this pass actually computes at each stage: a verify pass
        runs the full 1 + drafts window regardless of how many confirm."""
        if p.is_prompt:
            return p.state.trace.input_tokens
        return 1 + p.drafts if p.drafts else p.chunk

    def _pipe(self, p: _Pass) -> RequestPipeline:
        """The pipeline this pass walks: prompt passes walk the prefill
        replica's when disaggregated, everything else walks the decode
        pipeline."""
        if p.is_prompt and p.state.prefill_pipeline is not None:
            return p.state.prefill_pipeline
        return p.state.pipeline

    def _stage_work(self, p: _Pass) -> None:
        """Run this pass's current stage."""
        state = p.state
        if p.epoch != state.epoch:
            return                   # request restarted while we queued
        st = self._pipe(p).stages[p.stage_idx]
        ns = self.nodes.get(st.node)
        if ns is None or not ns.alive:
            self._restart_pass(p)
            return
        held = self.placement.assignment[st.node].num_layers
        frac = st.layers.num_layers / max(held, 1)
        if p.is_prompt:
            tokens = state.trace.input_tokens
            kv_need = tokens + min(self.kv_output_estimate,
                                   state.trace.output_tokens)
            state.kv_need = kv_need
            kv_grow = 0.0
        else:
            tokens = self._pass_tokens(p)
            kv_need = 0.0
            # decode grows KV only by the tokens that exceed the prompt-time
            # reservation (charging the full chunk when the estimate is first
            # crossed overcharged by up to decode_chunk-1 per node)
            reserved = min(self.kv_output_estimate,
                           state.trace.output_tokens)
            kv_grow = float(max(0, p.start + p.chunk
                                - max(reserved, p.start)))
        work = tokens * frac
        self._enqueue_work(st.node, work, kv_need, kv_grow,
                           lambda: self._stage_done(p), p)

    def _stage_done(self, p: _Pass) -> None:
        state = p.state
        if p.epoch != state.epoch:
            return
        pipe = self._pipe(p)
        st = pipe.stages[p.stage_idx]
        last = p.stage_idx == len(pipe.stages) - 1
        if p.is_prompt and state.prefill_pipeline is not None:
            self._fire_handoffs(state, st)
        if not last:
            nxt = pipe.stages[p.stage_idx + 1].node
            nbytes = self._pass_tokens(p) * self.model.activation_bytes
            p.stage_idx += 1
            self._route_transfer(st.node, nxt, nbytes,
                                 lambda: self._stage_work(p))
            return
        # pass complete -> token(s) to coordinator; with window room the
        # next chunk leaves for stage 0 from HERE, overlapping the return
        # hop — the ClusterRuntime's optimistic launch, modelled.  A verify
        # pass returns one greedy token per window position
        state.in_pipeline = False
        nbytes = self.model.token_bytes * (1 if p.is_prompt
                                           else self._pass_tokens(p))
        self._transfer(st.node, COORDINATOR, nbytes,
                       lambda: self._pass_done(p))
        self._launch_from(st.node, state)

    def _launch_from(self, src: str, state: _ReqState) -> None:
        """Launch the next decode pass if the in-flight window has room,
        output tokens remain uncovered, and no pass is inside the stages.
        Decode is autoregressive: a chunk's input token is produced only
        when the previous chunk exits the final stage, so at most ONE pass
        per request walks the pipeline at any time (exactly like the
        ClusterRuntime) — the window only absorbs the coordinator return
        path."""
        limit = self._limit(state)
        if state.kv_handoffs > 0:
            return                   # decode replica's KV still in flight
        if state.in_pipeline or state.inflight >= self.max_inflight \
                or state.launched >= limit:
            return
        if self.spec_tokens > 0:
            chunk, drafts = self._spec_chunk(limit - state.launched)
        else:
            chunk, drafts = min(self.decode_chunk,
                                limit - state.launched), 0
        p = _Pass(state, chunk=chunk, start=state.launched,
                  epoch=state.epoch, drafts=drafts)
        state.launched += chunk
        state.inflight += 1
        state.in_pipeline = True
        # a verify pass ships the confirmed token + every draft downstream
        self._route_transfer(src, state.pipeline.stages[0].node,
                             self.model.token_bytes * self._pass_tokens(p),
                             lambda pp=p: self._stage_work(pp))

    def _fire_handoffs(self, state: _ReqState, st) -> None:
        """Ship this prefill stage's filled KV to every decode stage whose
        layer range overlaps it (skipping mixed nodes, whose KV is already
        home), exactly like the runtime's per-stage handoff — earlier
        stages' transfers overlap later stages' compute."""
        for sd in state.pipeline.stages:
            if sd.node == st.node:
                continue
            lo = max(st.layers.start, sd.layers.start)
            hi = min(st.layers.end, sd.layers.end)
            if hi <= lo:
                continue
            nbytes = (self.model.kv_bytes_per_token_layer
                      * state.trace.input_tokens * (hi - lo))
            state.kv_handoffs += 1
            self._route_transfer(
                st.node, sd.node, nbytes,
                lambda s=state, e=state.epoch: self._handoff_done(s, e))

    def _handoff_done(self, state: _ReqState, epoch: int) -> None:
        if epoch != state.epoch:
            return
        state.kv_handoffs -= 1
        if state.kv_handoffs > 0:
            return
        # all KV landed: occupancy moves to the decode replica — release
        # the prefill-only nodes' charge, charge the decode nodes, and let
        # decode launch (the prompt token may have confirmed while KV was
        # in flight)
        decode_nodes = {sd.node for sd in state.pipeline.stages}
        for node in [n for n in list(state.kv_charged)
                     if n not in decode_nodes]:
            amt = state.kv_charged.pop(node)
            ns = self.nodes.get(node)
            if ns is not None:
                ns.kv_used = max(0.0, ns.kv_used - amt)
                self._admit_waiters(node)
        for node in decode_nodes:
            if node not in state.kv_charged and node in self.nodes:
                self._charge_kv(self.nodes[node], state, state.kv_need)
        self._launch_from(COORDINATOR, state)

    def _pass_done(self, p: _Pass) -> None:
        state = p.state
        if p.epoch != state.epoch:
            return
        state.inflight -= 1
        if p.is_prompt:
            state.first_token_s = self._now
            state.decoded = 1  # prompt pass emits the first output token
            if self._now >= self.warmup_s:
                self.metrics.prompt_latencies.append(
                    self._now - state.arrival_s)
                self.metrics.decoded_tokens += 1
                self.metrics.prompt_tokens += state.trace.input_tokens
        else:
            state.decoded += p.chunk
            if self._now >= self.warmup_s:
                self.metrics.decoded_tokens += p.chunk
                if p.drafts:
                    accepted = p.chunk - 1
                    self.metrics.spec_rounds += 1
                    self.metrics.spec_proposed += p.drafts
                    self.metrics.spec_accepted += accepted
                    self.metrics.spec_rejected += p.drafts - accepted
                    self.metrics.spec_confirmed += p.chunk
        if state.decoded >= self._limit(state):
            self._complete(state)
            return
        # window slack after confirmation (always the case at depth 1):
        # the next pass launches from the coordinator, the classic walk
        self._launch_from(COORDINATOR, state)

    def _complete(self, state: _ReqState) -> None:
        self._live.pop(state.trace.request_id, None)
        if self._now >= self.warmup_s:
            self.metrics.completed_requests += 1
            if state.first_token_s is not None and state.decoded > 1:
                per_tok = (self._now - state.first_token_s) / max(
                    1, state.decoded - 1)
                self.metrics.decode_latencies.append(per_tok)
        self._release_kv(state)
        self._finish_reservation(state)

    def _finish_reservation(self, state: _ReqState) -> None:
        """Release the scheduler's KV reservation with exactly the amount
        ``_arrive`` reserved (input + estimate) — releasing input + decoded
        instead leaks phantom usage whenever decoded < estimate, eventually
        pushing healthy nodes over the estimator's high-water mask.  The
        release goes to the scheduler that *made* the reservation: after a
        replan swap, releasing on the new estimator would erase other
        requests' reservations (per-node clamp at 0)."""
        amount = state.trace.input_tokens + self.kv_output_estimate
        sched = state.scheduler or self.scheduler
        sched.finish(state.pipeline, amount)
        if state.prefill_scheduler is not None \
                and state.prefill_pipeline is not None:
            state.prefill_scheduler.finish(state.prefill_pipeline, amount)

    def _restart_pass(self, p: _Pass) -> None:
        """Restart entry point for per-pass events (dead node, lost batch).
        With several passes of one request in flight, only the FIRST one to
        hit the failure restarts the request — the epoch bump turns the
        rest into no-ops instead of double-restarting."""
        if p.epoch != p.state.epoch:
            return
        self._restart(p.state)

    def _restart(self, state: _ReqState) -> None:
        """Request lost a node mid-flight: restart from the prompt phase on a
        freshly scheduled pipeline (KV on dead node is gone).  The abandoned
        pipeline's node + scheduler KV reservations are released here — the
        surviving nodes would otherwise leak them on every failure."""
        state.epoch += 1             # cancel every in-flight pass
        state.inflight = 0
        state.in_pipeline = False
        state.kv_handoffs = 0        # in-flight handoffs die with the epoch
        # deregister while reservations are released: a cancel landing in
        # the 0.1 s retry gap must not double-release (re-arrival re-registers)
        self._live.pop(state.trace.request_id, None)
        self.metrics.restarts += 1
        state.restarted += 1
        self._release_kv(state)
        self._finish_reservation(state)
        if state.restarted > 5:
            # drop pathological requests (reservations just released) —
            # counted, like the schedule-retry cap, so submitted always
            # reconciles with completed + dropped
            self._live.pop(state.trace.request_id, None)
            self.metrics.dropped_requests += 1
            return
        retry = TraceRequest(state.trace.request_id, self._now,
                             state.trace.input_tokens,
                             max(1, state.trace.output_tokens - state.decoded))
        self._push(self._now + 0.1, self._arrive, retry, state.restarted)

    # -- fault injection -------------------------------------------------------
    def fail_node(self, t: float, name: str) -> None:
        self._push(t, self._do_fail, name)

    def _do_fail(self, name: str) -> None:
        ns = self.nodes.get(name)
        if ns is None:
            return
        ns.alive = False
        # passes queued (or waiting on KV) at the dead node must restart
        # their requests, not silently vanish with reservations held on
        # other nodes
        stranded = [p for (_, _, p) in ns.pending]
        stranded += [p for (*_, p) in ns.kv_wait]
        ns.pending.clear()
        ns.kv_wait.clear()
        self.metrics.autoscale_events.append((self._now, "fail", name))
        if self.replan_fn is not None:
            new_sched, new_placement = self.replan_fn(name)
            self.scheduler = new_sched
            self.placement = new_placement
            for n, rng in new_placement.assignment.items():
                if n in self.nodes and self.nodes[n].alive:
                    self.nodes[n].rate = self.cluster.node_token_throughput(
                        n, self.model, rng.num_layers)
        for p in stranded:
            self._restart_pass(p)

    def slow_node(self, t: float, name: str, factor: float) -> None:
        self._push(t, self._do_slow, name, factor)

    def _do_slow(self, name: str, factor: float) -> None:
        ns = self.nodes.get(name)
        if ns is not None:
            ns.speed_factor = factor
            self.metrics.autoscale_events.append(
                (self._now, "slow", f"{name} x{factor}"))

    def record_autoscale(self, kind: str, detail: str) -> None:
        """Log a scale decision into the metrics (parity with the live
        ``Autoscaler.events`` — a replan_fn that grows or shrinks the
        cluster calls this so sim runs report the same event stream)."""
        self.metrics.autoscale_events.append((self._now, kind, detail))

    def cancel(self, t: float, request_id: int) -> None:
        """Client-disconnect parity hook: tear the request down at ``t``
        exactly as ``ClusterRuntime.cancel`` does — epoch bump (in-flight
        passes and handoffs die), node KV and scheduler reservations
        released — and count it."""
        self._push(t, self._do_cancel, request_id)

    def _do_cancel(self, request_id: int) -> None:
        state = self._live.pop(request_id, None)
        if state is None:
            return                   # finished, dropped, or never arrived
        state.epoch += 1
        state.inflight = 0
        state.in_pipeline = False
        state.kv_handoffs = 0
        self._release_kv(state)
        self._finish_reservation(state)
        self.metrics.cancelled_requests += 1

    # -- main loop ---------------------------------------------------------------
    def run(self, trace: List[TraceRequest]) -> Metrics:
        for req in trace:
            self._push(req.arrival_s, self._arrive, req)
        while self._events:
            t, _, fn, args = heapq.heappop(self._events)
            if t > self.horizon_s:
                break
            self._now = t
            fn(*args)
        self.metrics.horizon_s = min(self.horizon_s, max(self._now,
                                                         self.warmup_s))
        return self.metrics
