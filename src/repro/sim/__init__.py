"""Event-driven serving simulator for heterogeneous clusters."""
from .simulator import LinkSim, Metrics, NodeSim, Simulator
from .traces import (TraceRequest, azure_conversation_lengths, make_offline_trace,
                     make_trace, online_rate_for_cluster)
