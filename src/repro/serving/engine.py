"""Per-node serving engine: continuous batching over a layer slice.

This is the JAX analogue of the paper's per-node vLLM worker: each Helix
compute node runs an Engine over the *contiguous layer range* the MILP
assigned to it, with iteration-level (continuous) batching and a shared KV
pool across its local layers (§5.1 "a pool of pages unified for all local
layers").

The Engine here executes the whole model when given the full range (used by
the quickstart/serving examples), or a partial stack when given a Helix
stage (exercised in tests via ``layer_slice``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, init_caches, prefill
from .sampling import sample_token


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 512
    prompt_len: int = 128                 # static prompt bucket (left-pad)
    eos_token: int = -1                   # -1 = never stop early


class Engine:
    """Continuous-batching engine with fixed decode slots.

    Slots hold at most ``max_batch`` concurrent requests; prompts are
    left-padded into a static bucket so prefill compiles once; decode runs
    one jitted step for all active slots per iteration.
    """

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_batch
        self.caches = init_caches(cfg, engine_cfg.max_batch, engine_cfg.max_len)
        self.positions = jnp.zeros((engine_cfg.max_batch,), jnp.int32)
        self.tokens = jnp.zeros((engine_cfg.max_batch,), jnp.int32)
        self.active = np.zeros((engine_cfg.max_batch,), bool)
        self._rng = np.random.RandomState(rng_seed)
        self._decode = jax.jit(
            lambda params, tok, caches, pos: decode_step(cfg, params, tok,
                                                         caches, pos))
        self._prefill_one = jax.jit(
            lambda params, tok: prefill(cfg, params, tok,
                                        max_len=engine_cfg.max_len))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submitted_s = time.time()
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.ec.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # prefill this request alone (bucketed), then splice its caches
            # into the slot.  (A production engine would batch prefills;
            # chunked prefill is an optional follow-up.)
            prompt = req.prompt[-self.ec.prompt_len:]
            tok = jnp.asarray(prompt, jnp.int32)[None, :]
            logits, caches1 = self._prefill_one(self.params, tok)
            nxt = sample_token(np.asarray(logits)[0], req.temperature,
                               self._rng)
            req.output.append(int(nxt))
            req.first_token_s = time.time()
            self.caches = jax.tree.map(
                lambda full, one: _splice_slot(full, one, slot),
                self.caches, caches1)
            self.positions = self.positions.at[slot].set(len(prompt))
            self.tokens = self.tokens.at[slot].set(int(nxt))
            self.active[slot] = True
            self.slots[slot] = req

    @staticmethod
    def _batch_axis(x):
        return 0

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit + one decode step for active slots.
        Returns number of tokens produced."""
        self._admit()
        if not self.active.any():
            return 0
        logits, self.caches = self._decode(self.params, self.tokens,
                                           self.caches, self.positions)
        logits = np.asarray(logits)
        produced = 0
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            nxt = sample_token(logits[slot], req.temperature, self._rng)
            req.output.append(int(nxt))
            produced += 1
            done = (len(req.output) >= req.max_new_tokens
                    or int(nxt) == self.ec.eos_token)
            if done:
                req.done = True
                req.finished_s = time.time()
                self.slots[slot] = None
                self.active[slot] = False
        self.positions = self.positions + jnp.asarray(
            self.active.astype(np.int32))
        new_tokens = np.array(self.tokens)  # writable copy
        for slot, req in enumerate(self.slots):
            if req is not None:
                new_tokens[slot] = req.output[-1]
        self.tokens = jnp.asarray(new_tokens)
        return produced

    def run_until_done(self, max_iters: int = 10000) -> None:
        for _ in range(max_iters):
            if not self.queue and not self.active.any():
                return
            self.step()


def _splice_slot(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Copy a single-request cache leaf (batch=1 on some axis) into ``slot``
    of the engine-wide leaf.  Cache leaves carry batch on axis 0 (prologue)
    or axis 1 (stacked super-block caches: (repeats, batch, ...))."""
    if full.ndim == one.ndim and one.shape[0] == 1 \
            and full.shape[1:] == one.shape[1:]:
        return full.at[slot].set(one[0])
    if full.ndim == one.ndim and one.shape[1] == 1 \
            and full.shape[0] == one.shape[0] \
            and full.shape[2:] == one.shape[2:]:
        return full.at[:, slot].set(one[:, 0])
    raise ValueError(f"cannot splice cache leaf {one.shape} into {full.shape}")
