"""Per-node serving engine: continuous batching over a layer slice.

This is the JAX analogue of the paper's per-node vLLM worker: each Helix
compute node runs an Engine over the *contiguous layer range* the MILP
assigned to it, with iteration-level (continuous) batching.

Two engines share the Request/EngineConfig API:

  * ``Engine`` — dense per-slot caches sized (max_batch, max_len).  Simple,
    but memory is reserved rectangle-wise and prompts must fit the
    ``prompt_len`` bucket.
  * ``PagedEngine`` — KV lives in a ``kv_pool.PagePool`` shared across the
    node's local layers (§5.1 "a pool of pages unified for all local
    layers").  Prompts of any length prefill in ``prompt_len``-sized chunks
    that append pages; decode runs the Pallas paged_attention kernel for GQA
    layers with a dense fallback for MLA/SSM blocks; admission blocks (and
    decode preempts the newest request) when the pool is exhausted.

Both engines execute the whole model when given the full range (used by the
quickstart/serving examples), or a partial stack when given a Helix stage.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, init_caches, prefill
from ..models.paged import (absorb_dense_prefill, all_blocks_paged,
                            decode_step_paged, init_caches_paged,
                            num_paged_layers, paged_layer_counts,
                            prefill_chunk_paged)
from .kv_pool import PagePool
from .sampling import sample_token


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None   # "stop" | "length" when done
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    preemptions: int = 0


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 512                    # per-request token budget
    prompt_len: int = 128                 # prompt bucket (dense) / chunk (paged)
    eos_token: int = -1                   # -1 = never stop early


class _EngineBase:
    """Shared slot bookkeeping + sampling/termination logic."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_batch
        self.positions = np.zeros((engine_cfg.max_batch,), np.int32)
        self.tokens = np.zeros((engine_cfg.max_batch,), np.int32)
        self.active = np.zeros((engine_cfg.max_batch,), bool)
        self._rng = np.random.RandomState(rng_seed)

    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        self._validate(req)
        req.submitted_s = time.monotonic()
        self.queue.append(req)

    def _validate(self, req: Request) -> None:
        raise NotImplementedError

    def _finish(self, slot: int, req: Request, reason: str) -> None:
        req.done = True
        req.finish_reason = reason
        req.finished_s = time.monotonic()
        self.slots[slot] = None
        self.active[slot] = False

    def _first_token_done(self, req: Request, nxt: int, pos: int
                          ) -> Optional[str]:
        """Done-ness of a request whose only token so far came from prefill
        — checked *before* seating it, so a max_new_tokens=1 request never
        occupies a decode slot or burns a decode step."""
        if int(nxt) == self.ec.eos_token:
            return "stop"
        if req.max_new_tokens <= 1:
            return "length"
        if pos >= self.ec.max_len:
            return "length"          # prompt already filled the budget
        return None

    def _sample_slots(self, logits: np.ndarray) -> int:
        """Sample one token for every seated request, advance positions, and
        retire requests that hit eos / max_new_tokens / the length budget."""
        produced = 0
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            nxt = sample_token(logits[slot], req.temperature, self._rng)
            req.output.append(int(nxt))
            produced += 1
            self.positions[slot] += 1
            reason = None
            if int(nxt) == self.ec.eos_token:
                reason = "stop"
            elif len(req.output) >= req.max_new_tokens:
                reason = "length"
            elif self.positions[slot] >= self.ec.max_len:
                # cache/pool budget reached: hard termination, never write
                # past the end (the dense path previously grew ``positions``
                # unbounded and decode_step wrote out of range)
                reason = "length"
            if reason is not None:
                self._retire(slot, req, reason)
            else:
                self.tokens[slot] = int(nxt)
        return produced

    def _retire(self, slot: int, req: Request, reason: str) -> None:
        self._finish(slot, req, reason)

    def run_until_done(self, max_iters: int = 10000) -> None:
        for _ in range(max_iters):
            if not self.queue and not self.active.any():
                return
            self.step()
        if not self.queue and not self.active.any():
            return                   # finished exactly on the last step
        # never return silently with work outstanding (requests would just
        # look hung); mirror ClusterRuntime.run_until_done
        seated = [r.request_id for r in self.slots if r is not None]
        raise RuntimeError(
            f"not done after {max_iters} iterations; "
            f"queued={len(self.queue)} active={int(self.active.sum())} "
            f"active_requests={seated}")


class Engine(_EngineBase):
    """Continuous-batching engine with fixed dense decode slots.

    Slots hold at most ``max_batch`` concurrent requests; prompts must fit
    the ``prompt_len`` bucket (longer prompts raise — use PagedEngine, which
    chunks); decode runs one jitted step for all active slots per iteration
    and each request terminates at the ``max_len`` cache budget.
    """

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 rng_seed: int = 0):
        super().__init__(cfg, params, engine_cfg, rng_seed)
        self.caches = init_caches(cfg, engine_cfg.max_batch,
                                  engine_cfg.max_len)
        self._decode = jax.jit(
            lambda params, tok, caches, pos: decode_step(cfg, params, tok,
                                                         caches, pos))
        self._prefill_one = jax.jit(
            lambda params, tok: prefill(cfg, params, tok,
                                        max_len=engine_cfg.max_len))

    def _validate(self, req: Request) -> None:
        if len(req.prompt) > self.ec.prompt_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds the dense "
                f"engine's prompt_len bucket ({self.ec.prompt_len}); "
                "refusing to truncate — use PagedEngine (chunked prefill)")
        if len(req.prompt) > self.ec.max_len:
            raise ValueError(f"prompt of {len(req.prompt)} tokens exceeds "
                             f"max_len {self.ec.max_len}")

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.ec.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # prefill this request alone, then splice its caches into the
            # slot.  (A production engine would batch prefills.)
            prompt = np.asarray(req.prompt, np.int32)
            tok = jnp.asarray(prompt)[None, :]
            logits, caches1 = self._prefill_one(self.params, tok)
            nxt = sample_token(np.asarray(logits)[0], req.temperature,
                               self._rng)
            req.output.append(int(nxt))
            req.first_token_s = time.monotonic()
            reason = self._first_token_done(req, nxt, len(prompt))
            if reason is not None:
                self._finish(slot, req, reason)
                continue
            self.caches = jax.tree.map(
                lambda full, one: _splice_slot(full, one, slot),
                self.caches, caches1)
            self.positions[slot] = len(prompt)
            self.tokens[slot] = int(nxt)
            self.active[slot] = True
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit + one decode step for active slots.
        Returns number of tokens produced."""
        self._admit()
        if not self.active.any():
            return 0
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(self.tokens),
                                           self.caches,
                                           jnp.asarray(self.positions))
        return self._sample_slots(np.asarray(logits))


class PagedEngine(_EngineBase):
    """Continuous-batching engine over a unified KV page pool.

    Differences from the dense ``Engine``:
      * prompts of any length are accepted — all-paged stacks prefill in
        ``prompt_len``-sized chunks that append pages on demand; hybrid
        stacks (MLA/SSM/windowed blocks) prefill single-shot and scatter
        their GQA K/V into pages, keeping dense caches only for the
        fallback blocks;
      * decode runs ``paged_attention`` (Pallas) over the block tables;
      * capacity is the *pool*, not max_batch x max_len: admission blocks
        while the pool is full, and decode-time growth preempts the newest
        request (recompute-on-readmit) rather than overflowing;
      * a request hard-terminates when it reaches the ``max_len`` budget.

    ``interpret`` defaults to True off-TPU so the kernel runs under the
    Pallas interpreter on CPU.
    """

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 *, num_pages: Optional[int] = None, page_size: int = 16,
                 kv_dtype: Optional[str] = None,
                 interpret: Optional[bool] = None, rng_seed: int = 0):
        super().__init__(cfg, params, engine_cfg, rng_seed)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        ec = engine_cfg
        if num_pages is None:
            # full static allocation (one rectangle); pass a smaller pool to
            # oversubscribe and exercise admission control / preemption
            from .kv_pool import full_rectangle_pages
            num_pages = full_rectangle_pages(cfg, max_batch=ec.max_batch,
                                             max_len=ec.max_len,
                                             page_size=page_size)
        self.pool = PagePool(cfg, num_pages=num_pages, page_size=page_size,
                             max_batch=ec.max_batch, max_seq_len=ec.max_len,
                             kv_dtype=kv_dtype)
        self.caches = init_caches_paged(cfg, ec.max_batch, ec.max_len)
        self._all_paged = all_blocks_paged(cfg)
        self._n_pro, self._n_pp = paged_layer_counts(cfg)
        self._order = np.full((ec.max_batch,), -1, np.int64)
        self._admit_seq = 0

        # donate the pool buffers (pages + int8 scales) so decode updates
        # them in place — without this a VRAM-sized pool needs 2x its bytes
        # at every step (donation is a no-op on CPU and would only warn
        # there; donating the None scale pytrees of a bf16 pool is harmless)
        on_cpu = jax.default_backend() == "cpu"
        self._decode = jax.jit(
            lambda params, tok, caches, pos, kp, vp, ks, vs, tp, ts:
            decode_step_paged(cfg, params, tok, caches, pos, kp, vp, tp, ts,
                              k_scales=ks, v_scales=vs, interpret=interpret),
            donate_argnums=() if on_cpu else (4, 5, 6, 7))
        if self._all_paged:
            def _chunk(params, tok, start, kp, vp, ks, vs, tp, ts, *,
                       n_act: int):
                return prefill_chunk_paged(cfg, params, tok, start, kp, vp,
                                           tp, ts, k_scales=ks, v_scales=vs,
                                           active_blocks=n_act)
            self._prefill_chunk = jax.jit(
                _chunk, static_argnames=("n_act",),
                donate_argnums=() if on_cpu else (3, 4, 5, 6))
        else:
            self._prefill_one = jax.jit(
                lambda params, tok: prefill(cfg, params, tok,
                                            max_len=ec.max_len))

    def _validate(self, req: Request) -> None:
        if len(req.prompt) > self.ec.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds the pool's "
                f"per-request length budget ({self.ec.max_len}); refusing "
                "to truncate")

    # ------------------------------------------------------------------
    def _tables(self, slot: Optional[int] = None) -> Tuple[jax.Array,
                                                           jax.Array]:
        """Block tables as (prologue, super) device arrays; ``slot`` narrows
        to a single batch column (per-request prefill)."""
        t = self.pool.table if slot is None \
            else self.pool.table[:, slot:slot + 1]
        B = t.shape[1]
        tp = jnp.asarray(t[:self._n_pro])
        ts = jnp.asarray(t[self._n_pro:].reshape(
            self.cfg.repeats, self._n_pp, B, self.pool.blocks_per_seq))
        return tp, ts

    def _prefill(self, req: Request, slot: int) -> np.ndarray:
        """Prefill one request into its pages; returns last-token logits.
        A preempted request re-prefills prompt + already-generated tokens
        (recompute) so its output continues where it left off."""
        prompt = np.asarray(req.prompt, np.int32)
        if len(req.output) > 1:
            prompt = np.concatenate(
                [prompt, np.asarray(req.output[:-1], np.int32)])
        S = len(prompt)
        pool = self.pool
        if self._all_paged:
            # chunked prefill: no truncation at any length, pages appended
            # ahead of admission (ensure() already allocated them)
            chunk = max(1, self.ec.prompt_len)
            for off in range(0, S, chunk):
                tok = jnp.asarray(prompt[off:off + chunk])[None, :]
                tp, ts = self._tables(slot)
                n_act = _active_blocks_bucket(off + len(prompt[off:off + chunk]),
                                              pool.page, pool.blocks_per_seq)
                (logits, pool.k, pool.v, pool.k_scales,
                 pool.v_scales) = self._prefill_chunk(
                    self.params, tok, jnp.asarray([off], jnp.int32),
                    pool.k, pool.v, pool.k_scales, pool.v_scales, tp, ts,
                    n_act=n_act)
            return np.asarray(logits)[0]
        # hybrid stack: single-shot dense prefill (correct at any prompt
        # length), then move GQA K/V into pages and splice the dense
        # fallback caches (MLA/SSM/...) into this slot
        tok = jnp.asarray(prompt)[None, :]
        logits, caches1 = self._prefill_one(self.params, tok)
        (caches1, pool.k, pool.v, pool.k_scales,
         pool.v_scales) = absorb_dense_prefill(
            self.cfg, caches1, pool.k, pool.v, pool.table,
            slot, S, pool.page, k_scales=pool.k_scales,
            v_scales=pool.v_scales)
        self.caches = jax.tree.map(
            lambda full, one: _splice_slot(full, one, slot),
            self.caches, caches1)
        return np.asarray(logits)[0]

    def _admit(self) -> None:
        for slot in range(self.ec.max_batch):
            if not self.queue:
                return
            if self.slots[slot] is not None:
                continue
            req = self.queue[0]
            resumed = bool(req.output)      # preempted: recompute, not resample
            S = len(req.prompt) + max(0, len(req.output) - 1)
            # admission control: all prompt pages (plus the first decode
            # token's) must be allocatable now, else the request waits
            if not self.pool.ensure(slot, min(S + 1, self.ec.max_len)):
                return
            self.queue.popleft()
            logits = self._prefill(req, slot)
            if resumed:
                nxt = req.output[-1]        # already sampled before eviction
            else:
                nxt = sample_token(logits, req.temperature, self._rng)
                req.output.append(int(nxt))
                req.first_token_s = time.monotonic()
                reason = self._first_token_done(req, nxt, S)
                if reason is not None:
                    self.pool.release(slot)
                    self._finish(slot, req, reason)
                    continue
            self.positions[slot] = S
            self.tokens[slot] = int(nxt)
            self.active[slot] = True
            self.slots[slot] = req
            self._order[slot] = self._admit_seq
            self._admit_seq += 1

    # ------------------------------------------------------------------
    def _preempt(self, slot: int) -> None:
        """Evict a running request: free its pages and requeue it at the
        front.  Generated tokens are kept — readmission re-prefills
        prompt + output (vLLM-style recompute), so the visible output never
        retracts and temperature>0 requests aren't resampled."""
        req = self.slots[slot]
        self.pool.release(slot)
        req.preemptions += 1
        self.queue.appendleft(req)
        self.slots[slot] = None
        self.active[slot] = False
        self.positions[slot] = 0
        self.tokens[slot] = 0
        self._order[slot] = -1

    def _grow_or_preempt(self) -> None:
        """Allocate the pages each active slot needs for this decode step;
        when the pool runs dry, preempt the newest request (least completed
        work) until it fits — including the requester itself if it *is* the
        newest."""
        order = sorted((s for s in range(self.ec.max_batch)
                        if self.active[s]), key=lambda s: self._order[s])
        for slot in order:
            if not self.active[slot]:
                continue          # already preempted this round
            while not self.pool.ensure(slot, int(self.positions[slot]) + 1):
                live = [s for s in range(self.ec.max_batch)
                        if self.active[s]]
                victim = max(live, key=lambda s: self._order[s])
                self._preempt(victim)
                if victim == slot:
                    break

    def step(self) -> int:
        """One engine iteration: admit + grow/preempt + one paged decode
        step for active slots.  Returns number of tokens produced."""
        self._admit()
        if not self.active.any():
            return 0
        self._grow_or_preempt()
        if not self.active.any():
            return 0
        tp, ts = self._tables()
        pool = self.pool
        (logits, self.caches, pool.k, pool.v, pool.k_scales,
         pool.v_scales) = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.positions), pool.k, pool.v, pool.k_scales,
            pool.v_scales, tp, ts)
        return self._sample_slots(np.asarray(logits))

    def _retire(self, slot: int, req: Request, reason: str) -> None:
        self.pool.release(slot)
        self._order[slot] = -1
        self._finish(slot, req, reason)


def _active_blocks_bucket(tokens_through: int, page: int,
                          blocks_per_seq: int) -> int:
    """Static gather cap for a prefill chunk ending at ``tokens_through``:
    the next power of two >= ceil(tokens/page), clamped to the per-seq
    budget — bounds distinct jit specializations to log2(NP) while keeping
    short prompts from materializing the whole rectangle."""
    need = -(-tokens_through // page)
    b = 1
    while b < need:
        b <<= 1
    return min(b, blocks_per_seq)


def _splice_slot(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Copy a single-request cache leaf (batch=1 on some axis) into ``slot``
    of the engine-wide leaf.  Cache leaves carry batch on axis 0 (prologue)
    or axis 1 (stacked super-block caches: (repeats, batch, ...))."""
    if full.ndim == one.ndim and one.shape[0] == 1 \
            and full.shape[1:] == one.shape[1:]:
        return full.at[slot].set(one[0])
    if full.ndim == one.ndim and one.shape[1] == 1 \
            and full.shape[0] == one.shape[0] \
            and full.shape[2:] == one.shape[2:]:
        return full.at[:, slot].set(one[:, 0])
    raise ValueError(f"cannot splice cache leaf {one.shape} into {full.shape}")
