"""Serving engines: continuous batching over (partial) layer stacks."""
from .autoscaler import Autoscaler, AutoscaleEvent
from .engine import Engine, EngineConfig, PagedEngine, Request
from .frontend import (Frontend, RequestStats, decode_tokens, encode_text,
                       percentiles, summarize)
from .kv_pool import (PagePool, PoolExhausted, full_rectangle_pages,
                      page_bytes, pages_for_vram)
from .runtime import ClusterRuntime, InProcessTransport, Transport
from .sampling import sample_token
from .stage_engine import (DecodeItem, DecodeOut, PagedStageEngine,
                           StageEngine, make_stage_engine)
from .transport import (FrameError, RemoteStageEngine, SocketTransport,
                        StagedRef, TransportStalled, WorkerChannel,
                        WorkerDied, WorkerError, decode_payload,
                        encode_payload, payload_bytes, recv_frame,
                        send_frame)
