"""Serving engine: continuous batching over (partial) layer stacks."""
from .engine import Engine, EngineConfig, Request
from .sampling import sample_token
