"""Unified paged KV pool for the serving engine (paper §5.1).

One physical pool of ``num_pages`` K and V pages is shared by **all** of a
node's paged attention layers — the paper's "pool of pages unified for all
local layers".  A token occupies one row in one page *per paged layer*, so a
logical sequence block costs ``num_paged_layers`` physical pages.  Page 0 is
a scratch page: empty block-table entries point at it, so inactive batch
slots write/read it harmlessly inside the jitted decode step.

Pool-sizing math (see ``pages_for_vram``), per KV dtype:

    | quantity             | param dtype (bf16)        | kv_dtype="int8"     |
    |----------------------|---------------------------|---------------------|
    | kv element bytes     | 2                         | 1                   |
    | page_bytes           | 2 * page * KH * D * 2     | 2 * page * KH * D   |
    | scale_bytes / page   | 0                         | 2 * KH * 4 (f32)    |
    | num_pages            | pool_bytes // page_bytes  | pool_bytes //       |
    |                      |                           |  (page_bytes        |
    |                      |                           |   + scale_bytes)    |
    | token capacity       | (num_pages - 1) * page / n_paged_layers         |

plus the dtype-independent rows:

    | param_bytes (node)    | param_count * b * layers_on_node / num_layers |
    | pool bytes available  | vram_bytes - param_bytes                      |
    | per-seq budget (NP)   | ceil(max_seq_len / page_size) blocks          |
    | min viable pool       | 1 + NP * n_paged_layers pages                 |

With ``kv_dtype="int8"`` a page stores int8 elements plus one float32 absmax
scale per (page, kv_head) for K and V each, so page cost drops from
``4*page*KH*D`` bytes (K+V bf16) to ``2*page*KH*D + 8*KH`` — ≈2× the token
capacity at fixed VRAM (the scale overhead is ``4 / page_size`` of a percent
per element).  Unlike the dense engine's ``max_batch * max_len`` rectangle,
capacity is shared: many short sequences or a few long ones fit the same
pool, which is exactly the asymmetric-memory slack Helix's placement
exploits on heterogeneous nodes.

Allocation is on-demand (a block per ``page_size`` tokens, across layers),
freed on request completion/preemption; admission control blocks new
requests — and decode preempts the newest running request — when the pool is
exhausted, instead of overflowing.  The free list is a preallocated numpy
stack: growing a slot by ``n`` blocks is one vectorized slice pop covering
all ``n * num_layers`` pages (``alloc_ops`` counts these bulk operations,
not pages — tests pin the O(1) behaviour).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.paged import num_paged_layers


class PoolExhausted(RuntimeError):
    """Raised when a request needs more pages than the pool can ever hold."""


class PagePool:
    """Shared K/V page pool + per-slot block tables and a free list.

    Device arrays ``k``/``v`` have shape (num_pages, page_size, kv_heads,
    head_dim) and are updated functionally by the jitted model steps (the
    engine stores the returned arrays back).  With ``kv_dtype="int8"`` they
    are int8 and ``k_scales``/``v_scales`` hold the (num_pages, kv_heads)
    float32 per-page absmax scales (None otherwise).  The block table is a
    host ``(num_paged_layers, max_batch, blocks_per_seq)`` int32 array; row
    order is prologue layers first, then pattern positions repeat-major,
    matching ``models.paged`` layer numbering.
    """

    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 max_batch: int, max_seq_len: int, dtype=None,
                 paged_layers: Optional[int] = None,
                 kv_dtype: Optional[str] = None):
        self.cfg = cfg
        self.page = page_size
        # a stage engine's pool covers only the node's layer slice: pass the
        # slice's paged-block count so a token costs one page per *local*
        # paged layer, not per model layer
        self.num_layers = paged_layers if paged_layers is not None \
            else num_paged_layers(cfg)
        if self.num_layers == 0:
            raise ValueError(f"{cfg.name}: no full-attention GQA blocks — "
                             "nothing to page; use the dense engine")
        self.blocks_per_seq = -(-max_seq_len // page_size)
        min_pages = 1 + self.blocks_per_seq * self.num_layers
        if num_pages < min_pages:
            raise ValueError(
                f"pool of {num_pages} pages cannot hold one full request: "
                f"need >= {min_pages} (1 scratch + {self.blocks_per_seq} "
                f"blocks x {self.num_layers} layers)")
        if kv_dtype not in (None, "param", "int8"):
            raise ValueError(f"kv_dtype must be 'param' or 'int8', "
                             f"got {kv_dtype!r}")
        self.kv_dtype = "int8" if kv_dtype == "int8" else "param"
        self.quantized = self.kv_dtype == "int8"
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        if self.quantized:
            dtype = jnp.int8
            self.k_scales = jnp.zeros((num_pages, kh), jnp.float32)
            self.v_scales = jnp.zeros((num_pages, kh), jnp.float32)
        else:
            if dtype is None:
                dtype = {"bfloat16": jnp.bfloat16,
                         "float32": jnp.float32}[cfg.param_dtype]
            self.k_scales = None
            self.v_scales = None
        self.num_pages = num_pages
        self.k = jnp.zeros((num_pages, page_size, kh, hd), dtype)
        self.v = jnp.zeros((num_pages, page_size, kh, hd), dtype)
        # page 0 reserved as scratch; the free list is a preallocated stack
        # whose live region is _free[:_free_top] (top of stack at the end,
        # matching the old list.pop() order: page 1 first, then 2, ...)
        self._free = np.arange(num_pages - 1, 0, -1, dtype=np.int32)
        self._free_top = num_pages - 1
        self.alloc_ops = 0          # bulk ensure/release ops (not pages)
        self.table = np.zeros((self.num_layers, max_batch,
                               self.blocks_per_seq), np.int32)
        self._nblocks = np.zeros((max_batch,), np.int64)

    # ------------------------------------------------------------------
    @property
    def used(self) -> int:
        """Pages currently allocated (scratch page excluded)."""
        return (self.num_pages - 1) - self._free_top

    @property
    def tokens_used(self) -> int:
        """Token capacity currently allocated (block granularity) — what the
        scheduler's KVEstimator should see as this node's true occupancy."""
        return int(self._nblocks.sum()) * self.page

    @property
    def tokens_capacity(self) -> int:
        """Total token capacity of the pool (block granularity)."""
        return ((self.num_pages - 1) // self.num_layers) * self.page

    def capacity_tokens(self, slot: int) -> int:
        return int(self._nblocks[slot]) * self.page

    def pages_needed(self, slot: int, tokens: int) -> int:
        blocks = -(-tokens // self.page) - int(self._nblocks[slot])
        return max(0, blocks) * self.num_layers

    def can_fit(self, slot: int, tokens: int) -> bool:
        return self.pages_needed(slot, tokens) <= self._free_top

    def ensure(self, slot: int, tokens: int) -> bool:
        """Grow ``slot``'s allocation to hold ``tokens``.  Returns False if
        the pool is currently exhausted (caller blocks or preempts); raises
        PoolExhausted if ``tokens`` exceeds the per-sequence budget.

        Doubles as the in-flight reservation primitive: the ClusterRuntime
        calls it on every stage node when it *launches* a decode pass, so by
        the time the token reaches a mid-pipeline node its block is already
        held — allocated blocks can only be taken back by release or
        preemption, never by another request's growth.

        One call is one batched pop from the free-list stack no matter how
        many blocks x layers it covers."""
        target = -(-tokens // self.page)
        if target > self.blocks_per_seq:
            raise PoolExhausted(
                f"{tokens} tokens > per-sequence budget "
                f"{self.blocks_per_seq * self.page}")
        if not self.can_fit(slot, tokens):
            return False
        j0 = int(self._nblocks[slot])
        grow = target - j0
        if grow <= 0:
            return True
        n = grow * self.num_layers
        # stack pop order matches the old per-page loop: layer index fastest,
        # block index outer — popped[i] is the i-th page the loop would take
        popped = self._free[self._free_top - n:self._free_top][::-1]
        self._free_top -= n
        self.table[:, slot, j0:j0 + grow] = \
            popped.reshape(grow, self.num_layers).T
        self._nblocks[slot] = target
        self.alloc_ops += 1
        return True

    def release(self, slot: int) -> None:
        """Return all of ``slot``'s pages to the free list (one batched
        push)."""
        nb = int(self._nblocks[slot])
        if nb:
            n = nb * self.num_layers
            # push order matches the old loop: block outer, layer fastest
            self._free[self._free_top:self._free_top + n] = \
                self.table[:, slot, :nb].T.reshape(-1)
            self._free_top += n
            self.alloc_ops += 1
        self.table[:, slot, :] = 0
        self._nblocks[slot] = 0

    def truncate(self, slot: int, tokens: int) -> None:
        """Shrink ``slot``'s allocation to hold exactly ``tokens`` rows —
        the page-frontier rollback primitive for rejected speculative
        drafts.  Blocks past the new frontier go back to the free list in
        one batched push (``alloc_ops`` counts it like ensure/release).

        Rolled-back rows inside the *kept* frontier block are not zeroed
        here: param-dtype attention masks them by position, and on the int8
        path ``quantized_append`` recomputes a page's scale purely from its
        live rows (zeroing rows past the append window first), so a freed
        page self-cleans on reuse.  The int8-exact restore of the kept
        frontier page's bytes+scales is the engine's job (it snapshots the
        page after each verify sub-step — see PagedStageEngine.rollback)."""
        target = -(-tokens // self.page)
        nb = int(self._nblocks[slot])
        if target >= nb:
            return
        n = (nb - target) * self.num_layers
        # push order matches release: block outer, layer fastest
        self._free[self._free_top:self._free_top + n] = \
            self.table[:, slot, target:nb].T.reshape(-1)
        self._free_top += n
        self.table[:, slot, target:nb] = 0
        self._nblocks[slot] = target
        self.alloc_ops += 1


def full_rectangle_pages(cfg: ModelConfig, *, max_batch: int, max_len: int,
                         page_size: int,
                         paged_layers: Optional[int] = None) -> int:
    """Pages for a dense-equivalent full allocation — every slot holding its
    whole ``max_len`` budget — plus the scratch page.  Pools this size can
    never block or preempt; smaller pools oversubscribe.  ``paged_layers``
    overrides the model-wide paged-block count for stage-slice pools.
    (Page *counts* are dtype-independent — int8 shrinks page_bytes, not the
    block math.)"""
    blocks = -(-max_len // page_size)
    layers = paged_layers if paged_layers is not None \
        else num_paged_layers(cfg)
    return 1 + blocks * layers * max_batch


def page_bytes(cfg: ModelConfig, page_size: int,
               kv_dtype: Optional[str] = None) -> float:
    """Bytes one pool page costs (K + V + int8 scale rows, if any)."""
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kv_dtype == "int8":
        return 2 * page_size * kh * hd * 1 + 2 * kh * 4
    elt = {"bfloat16": 2, "float32": 4}[cfg.param_dtype]
    return 2 * page_size * kh * hd * elt


def pages_for_vram(cfg: ModelConfig, vram_bytes: float, *, page_size: int,
                   layers_on_node: Optional[int] = None,
                   max_pages: Optional[int] = None,
                   kv_dtype: Optional[str] = None) -> int:
    """Size a pool from node VRAM the way ``sim.Simulator`` sizes its KV
    capacity: whatever VRAM the node's parameter slice does not use becomes
    pages.  ``layers_on_node`` is the Helix layer-slice size (defaults to the
    whole model); ``max_pages`` caps the result (useful for smoke models
    whose tiny pages would otherwise number in the millions).
    ``kv_dtype="int8"`` halves the per-page cost (1-byte elements plus
    ``2 * kv_heads * 4`` scale bytes per page) — ≈2x the pages from the same
    VRAM."""
    elt = {"bfloat16": 2, "float32": 4}[cfg.param_dtype]
    pb = page_bytes(cfg, page_size, kv_dtype)
    layers = layers_on_node if layers_on_node is not None else cfg.num_layers
    param_bytes = cfg.param_count() * elt * layers / max(cfg.num_layers, 1)
    free = max(0.0, vram_bytes - param_bytes)
    pages = int(free // pb)
    if max_pages is not None:
        pages = min(pages, max_pages)
    return pages
