"""Unified paged KV pool for the serving engine (paper §5.1).

One physical pool of ``num_pages`` K and V pages is shared by **all** of a
node's paged attention layers — the paper's "pool of pages unified for all
local layers".  A token occupies one row in one page *per paged layer*, so a
logical sequence block costs ``num_paged_layers`` physical pages.  Page 0 is
a scratch page: empty block-table entries point at it, so inactive batch
slots write/read it harmlessly inside the jitted decode step.

Pool-sizing math (see ``pages_for_vram``):

    | quantity              | formula                                       |
    |-----------------------|-----------------------------------------------|
    | page_bytes            | 2 (K+V) * page_size * kv_heads * head_dim * b |
    | param_bytes (node)    | param_count * b * layers_on_node / num_layers |
    | pool bytes available  | vram_bytes - param_bytes                      |
    | num_pages             | pool_bytes // page_bytes                      |
    | token capacity        | (num_pages - 1) * page_size / n_paged_layers  |
    | per-seq budget (NP)   | ceil(max_seq_len / page_size) blocks          |
    | min viable pool       | 1 + NP * n_paged_layers pages                 |

where ``b`` is bytes per element (2 for bfloat16).  Unlike the dense engine's
``max_batch * max_len`` rectangle, capacity is shared: many short sequences
or a few long ones fit the same pool, which is exactly the asymmetric-memory
slack Helix's placement exploits on heterogeneous nodes.

Allocation is on-demand (a block per ``page_size`` tokens, across layers),
freed on request completion/preemption; admission control blocks new
requests — and decode preempts the newest running request — when the pool is
exhausted, instead of overflowing.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.paged import num_paged_layers


class PoolExhausted(RuntimeError):
    """Raised when a request needs more pages than the pool can ever hold."""


class PagePool:
    """Shared K/V page pool + per-slot block tables and a free list.

    Device arrays ``k``/``v`` have shape (num_pages, page_size, kv_heads,
    head_dim) and are updated functionally by the jitted model steps (the
    engine stores the returned arrays back).  The block table is a host
    ``(num_paged_layers, max_batch, blocks_per_seq)`` int32 array; row order
    is prologue layers first, then pattern positions repeat-major, matching
    ``models.paged`` layer numbering.
    """

    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 max_batch: int, max_seq_len: int, dtype=None,
                 paged_layers: Optional[int] = None):
        self.cfg = cfg
        self.page = page_size
        # a stage engine's pool covers only the node's layer slice: pass the
        # slice's paged-block count so a token costs one page per *local*
        # paged layer, not per model layer
        self.num_layers = paged_layers if paged_layers is not None \
            else num_paged_layers(cfg)
        if self.num_layers == 0:
            raise ValueError(f"{cfg.name}: no full-attention GQA blocks — "
                             "nothing to page; use the dense engine")
        self.blocks_per_seq = -(-max_seq_len // page_size)
        min_pages = 1 + self.blocks_per_seq * self.num_layers
        if num_pages < min_pages:
            raise ValueError(
                f"pool of {num_pages} pages cannot hold one full request: "
                f"need >= {min_pages} (1 scratch + {self.blocks_per_seq} "
                f"blocks x {self.num_layers} layers)")
        if dtype is None:
            dtype = {"bfloat16": jnp.bfloat16,
                     "float32": jnp.float32}[cfg.param_dtype]
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        self.num_pages = num_pages
        self.k = jnp.zeros((num_pages, page_size, kh, hd), dtype)
        self.v = jnp.zeros((num_pages, page_size, kh, hd), dtype)
        # page 0 reserved as scratch; free list is a stack of page ids
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.table = np.zeros((self.num_layers, max_batch,
                               self.blocks_per_seq), np.int32)
        self._nblocks = np.zeros((max_batch,), np.int64)

    # ------------------------------------------------------------------
    @property
    def used(self) -> int:
        """Pages currently allocated (scratch page excluded)."""
        return (self.num_pages - 1) - len(self._free)

    @property
    def tokens_used(self) -> int:
        """Token capacity currently allocated (block granularity) — what the
        scheduler's KVEstimator should see as this node's true occupancy."""
        return int(self._nblocks.sum()) * self.page

    @property
    def tokens_capacity(self) -> int:
        """Total token capacity of the pool (block granularity)."""
        return ((self.num_pages - 1) // self.num_layers) * self.page

    def capacity_tokens(self, slot: int) -> int:
        return int(self._nblocks[slot]) * self.page

    def pages_needed(self, slot: int, tokens: int) -> int:
        blocks = -(-tokens // self.page) - int(self._nblocks[slot])
        return max(0, blocks) * self.num_layers

    def can_fit(self, slot: int, tokens: int) -> bool:
        return self.pages_needed(slot, tokens) <= len(self._free)

    def ensure(self, slot: int, tokens: int) -> bool:
        """Grow ``slot``'s allocation to hold ``tokens``.  Returns False if
        the pool is currently exhausted (caller blocks or preempts); raises
        PoolExhausted if ``tokens`` exceeds the per-sequence budget.

        Doubles as the in-flight reservation primitive: the ClusterRuntime
        calls it on every stage node when it *launches* a decode pass, so by
        the time the token reaches a mid-pipeline node its block is already
        held — allocated blocks can only be taken back by release or
        preemption, never by another request's growth."""
        target = -(-tokens // self.page)
        if target > self.blocks_per_seq:
            raise PoolExhausted(
                f"{tokens} tokens > per-sequence budget "
                f"{self.blocks_per_seq * self.page}")
        if not self.can_fit(slot, tokens):
            return False
        while self._nblocks[slot] < target:
            j = int(self._nblocks[slot])
            for li in range(self.num_layers):
                self.table[li, slot, j] = self._free.pop()
            self._nblocks[slot] += 1
        return True

    def release(self, slot: int) -> None:
        """Return all of ``slot``'s pages to the free list."""
        for j in range(int(self._nblocks[slot])):
            for li in range(self.num_layers):
                self._free.append(int(self.table[li, slot, j]))
        self.table[:, slot, :] = 0
        self._nblocks[slot] = 0


def full_rectangle_pages(cfg: ModelConfig, *, max_batch: int, max_len: int,
                         page_size: int,
                         paged_layers: Optional[int] = None) -> int:
    """Pages for a dense-equivalent full allocation — every slot holding its
    whole ``max_len`` budget — plus the scratch page.  Pools this size can
    never block or preempt; smaller pools oversubscribe.  ``paged_layers``
    overrides the model-wide paged-block count for stage-slice pools."""
    blocks = -(-max_len // page_size)
    layers = paged_layers if paged_layers is not None \
        else num_paged_layers(cfg)
    return 1 + blocks * layers * max_batch


def pages_for_vram(cfg: ModelConfig, vram_bytes: float, *, page_size: int,
                   layers_on_node: Optional[int] = None,
                   max_pages: Optional[int] = None) -> int:
    """Size a pool from node VRAM the way ``sim.Simulator`` sizes its KV
    capacity: whatever VRAM the node's parameter slice does not use becomes
    pages.  ``layers_on_node`` is the Helix layer-slice size (defaults to the
    whole model); ``max_pages`` caps the result (useful for smoke models
    whose tiny pages would otherwise number in the millions)."""
    elt = {"bfloat16": 2, "float32": 4}[cfg.param_dtype]
    page_bytes = 2 * page_size * cfg.num_kv_heads * cfg.resolved_head_dim * elt
    layers = layers_on_node if layers_on_node is not None else cfg.num_layers
    param_bytes = cfg.param_count() * elt * layers / max(cfg.num_layers, 1)
    free = max(0.0, vram_bytes - param_bytes)
    pages = int(free // page_bytes)
    if max_pages is not None:
        pages = min(pages, max_pages)
    return pages
