"""The online front door: an OpenAI-compatible HTTP API over ClusterRuntime.

Helix evaluates an *online* setting — requests arrive on the wall clock and
per-request latency (TTFT, TPOT, SLO attainment) is the headline metric —
so this module turns the offline trace-replay runtime into a server:

  POST /v1/completions        OpenAI completions (``stream: true`` → SSE)
  POST /v1/chat/completions   OpenAI chat completions (SSE likewise)
  GET  /v1/models             the single served model
  GET  /healthz               liveness + runtime ``_state()`` diagnostics
                              + the server-side latency summary so far

Streaming semantics: one SSE ``data:`` chunk per token the coordinator
*confirms* — the runtime's ``on_token`` callback fires in strict output
order, so pipelined ``max_inflight`` windows and speculative verify rounds
never leak unconfirmed (cancellable) tokens into a stream.  Each chunk
carries ``token_id`` and ``output_index``; the terminal chunk carries
``finish_reason``, followed by ``data: [DONE]``.

Admission: requests the runtime rejects up front (empty prompt, prompt >
``max_len``, sampling × speculation) map to HTTP 400; when accepted-but-
unfinished work reaches ``max_pending`` the server answers 429 with a
``Retry-After`` hint instead of letting queues grow without bound.  During
a drain (``shutdown(drain=True)``) new requests get 503 while in-flight
streams run to completion.

Tokenisation: the repo has no text tokenizer, so the API accepts either a
raw token-id list (exact control — used by the byte-identity tests and the
open-loop client) or a string, encoded as UTF-8 bytes (every config here
has vocab_size >= 256, so byte ids are always in-vocab; ids < 256 decode
back through latin-1, larger ids render as ``<id>``).

Everything is stdlib: ``http.server.ThreadingHTTPServer`` handlers call
the runtime's thread-safe ``submit()`` and block on a per-request queue
fed from the loop thread — no new dependencies.
"""
from __future__ import annotations

import dataclasses
import json
import queue as _queue
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import Request
from .runtime import ClusterRuntime

# ---------------------------------------------------------------------------
# tokenizer-less text codec


def encode_text(text: str, vocab_size: int) -> List[int]:
    """UTF-8 bytes as token ids (folded into the vocab for tiny vocabs)."""
    return [b % vocab_size for b in text.encode("utf-8")]


def decode_token(tok: int) -> str:
    if 0 <= tok < 256:
        return bytes([tok]).decode("latin-1")
    return f"<{tok}>"


def decode_tokens(toks: Sequence[int]) -> str:
    return "".join(decode_token(int(t)) for t in toks)


# ---------------------------------------------------------------------------
# per-request latency metrics


def percentiles(xs: Sequence[float],
                qs: Tuple[int, ...] = (50, 95, 99)) -> Dict[str, float]:
    if not xs:
        return {f"p{q}": float("nan") for q in qs}
    a = np.asarray(list(xs), np.float64)
    return {f"p{q}": float(np.percentile(a, q)) for q in qs}


@dataclasses.dataclass
class RequestStats:
    """Server-side latency record, all on the runtime's monotonic clock."""
    request_id: int
    ttft_s: float                # submit -> first confirmed token
    tpot_s: float                # mean per-token time after the first
    e2e_s: float                 # submit -> finish
    tokens: int
    finish_reason: str

    @classmethod
    def from_request(cls, req: Request) -> "RequestStats":
        first = req.first_token_s if req.first_token_s is not None \
            else req.finished_s
        n = len(req.output)
        tpot = ((req.finished_s - first) / (n - 1)) if n > 1 else 0.0
        return cls(request_id=req.request_id,
                   ttft_s=first - req.submitted_s,
                   tpot_s=tpot,
                   e2e_s=req.finished_s - req.submitted_s,
                   tokens=n,
                   finish_reason=req.finish_reason or "")


def summarize(stats: Sequence[RequestStats], *,
              slo_ttft_s: Optional[float] = None,
              slo_tpot_s: Optional[float] = None) -> Dict[str, Any]:
    """TTFT/TPOT/E2E percentiles + SLO attainment.  A request attains its
    SLO when TTFT <= slo_ttft_s AND (for multi-token outputs) mean TPOT <=
    slo_tpot_s; with no SLO configured attainment is reported over an
    always-true predicate (1.0) so the field is uniformly present."""
    out: Dict[str, Any] = {
        "requests": len(stats),
        "ttft_s": percentiles([s.ttft_s for s in stats]),
        "tpot_s": percentiles([s.tpot_s for s in stats if s.tokens > 1]),
        "e2e_s": percentiles([s.e2e_s for s in stats]),
    }
    if stats:
        ok = 0
        for s in stats:
            good = True
            if slo_ttft_s is not None:
                good = good and s.ttft_s <= slo_ttft_s
            if slo_tpot_s is not None and s.tokens > 1:
                good = good and s.tpot_s <= slo_tpot_s
            ok += bool(good)
        out["slo_attainment"] = ok / len(stats)
    else:
        out["slo_attainment"] = float("nan")
    out["slo"] = {"ttft_s": slo_ttft_s, "tpot_s": slo_tpot_s}
    return out


# ---------------------------------------------------------------------------
# the server


class Frontend:
    """OpenAI-compatible HTTP front door over a ``ClusterRuntime``.

    ``serve(host, port)`` starts two threads: the runtime's
    ``serve_forever`` loop and the ``ThreadingHTTPServer``; handlers feed
    the loop through ``runtime.submit(..., on_token=..., on_done=...)``.
    The runtime should be constructed with ``realtime=True`` (or a
    realtime transport) so arrivals land on the wall clock.
    """

    def __init__(self, runtime: ClusterRuntime, *,
                 model_name: Optional[str] = None,
                 max_pending: int = 64,
                 default_max_tokens: int = 16,
                 request_timeout_s: float = 300.0,
                 slo_ttft_s: Optional[float] = None,
                 slo_tpot_s: Optional[float] = None):
        self.rt = runtime
        self.model = model_name or runtime.cfg.name
        self.max_pending = max_pending
        self.default_max_tokens = default_max_tokens
        self.request_timeout_s = request_timeout_s
        self.slo_ttft_s = slo_ttft_s
        self.slo_tpot_s = slo_tpot_s
        self.stats: List[RequestStats] = []
        self.draining = False
        self.loop_error: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._next_id = 0
        # live traffic signals for the autoscaler: arrival timestamps on
        # the runtime clock, and completed (input_len, output_len) pairs
        # feeding TrafficProfile.from_requests
        self.arrivals: deque = deque(maxlen=4096)
        self.lengths: deque = deque(maxlen=4096)
        self.autoscaler = None       # attached by Autoscaler.attach()
        self._loop: Optional[threading.Thread] = None
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._httpd_thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------
    def serve(self, host: str = "127.0.0.1", port: int = 0
              ) -> Tuple[str, int]:
        """Start the runtime loop + HTTP server; returns the bound
        (host, port) — port 0 picks an ephemeral port."""
        def loop():
            try:
                self.rt.serve_forever()
            except BaseException as e:   # surfaced via /healthz + shutdown
                self.loop_error = e
        self._loop = threading.Thread(target=loop, daemon=True,
                                      name="runtime-loop")
        self._loop.start()

        fe = self

        class Handler(_Handler):
            frontend = fe

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._httpd_thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True, name="http-accept")
        self._httpd_thread.start()
        return self._httpd.server_address[:2]

    def begin_drain(self) -> None:
        """Stop accepting new requests (503) while in-flight ones finish."""
        self.draining = True

    def shutdown(self, drain: bool = True, timeout_s: float = 60.0) -> None:
        """Graceful stop: refuse new work, optionally wait for in-flight
        requests to finish streaming, then stop the loop and the HTTP
        server.  The runtime itself (worker processes etc.) is left to the
        caller's ``runtime.shutdown()``."""
        self.begin_drain()
        deadline = time.monotonic() + timeout_s
        if drain:
            while (self.rt.pending() > 0 and self.loop_error is None
                   and time.monotonic() < deadline):
                time.sleep(0.02)
        self.rt.stop_serving()
        if self._loop is not None:
            self._loop.join(timeout=max(0.0, deadline - time.monotonic()))
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()

    # -- request plumbing ---------------------------------------------------
    def alloc_request_id(self) -> int:
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            return rid

    def note_arrival(self, prompt_len: int) -> None:
        with self._lock:
            self.arrivals.append(self.rt.clock())

    def arrival_rate(self, window_s: float = 30.0) -> float:
        """Accepted requests/s over the trailing window (runtime clock).
        Cancelled requests stopped consuming capacity when they were torn
        down, so arrivals — not completions — are the demand signal."""
        now = self.rt.clock()
        with self._lock:
            n = sum(1 for t in self.arrivals if now - t <= window_s)
        return n / window_s if window_s > 0 else 0.0

    def record(self, req: Request) -> None:
        with self._lock:
            self.stats.append(RequestStats.from_request(req))
            self.lengths.append((int(len(req.prompt)),
                                 max(1, len(req.output))))

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            stats = list(self.stats)
        return summarize(stats, slo_ttft_s=self.slo_ttft_s,
                         slo_tpot_s=self.slo_tpot_s)

    def parse_prompt(self, body: Dict[str, Any], chat: bool) -> List[int]:
        """Token ids from an OpenAI request body.  Raises ValueError."""
        vocab = self.rt.cfg.vocab_size
        if chat:
            msgs = body.get("messages")
            if not isinstance(msgs, list) or not msgs:
                raise ValueError("messages must be a non-empty list")
            text = "".join(f"{m.get('role', 'user')}: {m.get('content', '')}\n"
                           for m in msgs) + "assistant:"
            return encode_text(text, vocab)
        p = body.get("prompt")
        if isinstance(p, str):
            return encode_text(p, vocab)
        if isinstance(p, list) and all(isinstance(t, int) for t in p):
            bad = [t for t in p if not 0 <= t < vocab]
            if bad:
                raise ValueError(f"token ids {bad[:4]} out of vocab "
                                 f"[0, {vocab})")
            return [int(t) for t in p]
        raise ValueError("prompt must be a string or a list of token ids")


class _Handler(BaseHTTPRequestHandler):
    """One handler thread per connection (ThreadingHTTPServer)."""

    frontend: Frontend = None    # set by the per-Frontend subclass
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):   # keep test/CI output clean
        pass

    # -- plumbing -----------------------------------------------------------
    def _json(self, code: int, obj: Dict[str, Any],
              headers: Sequence[Tuple[str, str]] = ()) -> None:
        body = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in headers:
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str,
               headers: Sequence[Tuple[str, str]] = ()) -> None:
        self._json(code, {"error": {"message": message,
                                    "type": "invalid_request_error"
                                    if code == 400 else "server_error",
                                    "code": code}}, headers)

    def _read_body(self) -> Optional[Dict[str, Any]]:
        try:
            n = int(self.headers.get("Content-Length", "0"))
            raw = self.rfile.read(n) if n else b"{}"
            obj = json.loads(raw.decode("utf-8"))
            if not isinstance(obj, dict):
                raise ValueError("body must be a JSON object")
            return obj
        except (ValueError, UnicodeDecodeError) as e:
            self._error(400, f"invalid JSON body: {e}")
            return None

    # -- routes -------------------------------------------------------------
    def do_GET(self) -> None:
        fe = self.frontend
        if self.path == "/v1/models":
            self._json(200, {"object": "list", "data": [
                {"id": fe.model, "object": "model", "owned_by": "repro"}]})
        elif self.path == "/healthz":
            try:
                state = fe.rt._state()   # loop may mutate under us: best-effort
            except Exception as e:
                state = f"unavailable: {e}"
            status = "error" if fe.loop_error is not None else \
                "draining" if fe.draining else "ok"
            try:
                pool = fe.rt.pool_pages_used()
            except Exception:
                pool = {}
            self._json(200 if status != "error" else 500, {
                "status": status,
                "model": fe.model,
                "pending": fe.rt.pending(),
                "completed": fe.rt.completed,
                "tokens_produced": fe.rt.tokens_produced,
                "cancelled_requests": fe.rt.cancelled_requests,
                "pool_pages_used": pool,
                "arrival_rate_rps": fe.arrival_rate(),
                "autoscaler": (fe.autoscaler.describe()
                               if fe.autoscaler is not None else None),
                "error": repr(fe.loop_error) if fe.loop_error else None,
                "state": state,
                "metrics": fe.summary(),
            })
        else:
            self._error(404, f"no route {self.path}")

    def do_POST(self) -> None:
        if self.path == "/v1/completions":
            self._completion(chat=False)
        elif self.path == "/v1/chat/completions":
            self._completion(chat=True)
        else:
            self._error(404, f"no route {self.path}")

    # -- completions --------------------------------------------------------
    def _completion(self, chat: bool) -> None:
        fe = self.frontend
        body = self._read_body()
        if body is None:
            return
        if fe.draining:
            self._error(503, "server is draining")
            return
        if fe.loop_error is not None:
            self._error(500, f"runtime loop died: {fe.loop_error!r}")
            return
        try:
            prompt = fe.parse_prompt(body, chat)
        except ValueError as e:
            self._error(400, str(e))
            return
        max_tokens = int(body.get("max_tokens", fe.default_max_tokens))
        temperature = float(body.get("temperature", 0.0))
        stream = bool(body.get("stream", False))
        # admission: bounded accepted-but-unfinished work
        if fe.rt.pending() >= fe.max_pending:
            self._error(429, f"at capacity ({fe.max_pending} pending "
                        "requests); retry later",
                        headers=[("Retry-After", "1")])
            return
        rid = fe.alloc_request_id()
        req = Request(request_id=rid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_tokens,
                      temperature=temperature)
        ch: "_queue.Queue" = _queue.Queue()
        try:
            fe.rt.submit(req,
                         on_token=lambda t: ch.put(("tok", t)),
                         on_done=lambda r: ch.put(("done", r)))
        except ValueError as e:
            self._error(400, str(e))
            return
        fe.note_arrival(len(prompt))
        if stream:
            self._stream_response(req, ch, chat)
        else:
            self._full_response(req, ch, chat)

    def _chunk(self, req: Request, chat: bool, *, idx: int,
               tok: Optional[int], finish: Optional[str]) -> bytes:
        text = decode_token(tok) if tok is not None else ""
        if chat:
            choice: Dict[str, Any] = {
                "index": 0,
                "delta": ({"role": "assistant", "content": text}
                          if tok is not None else {}),
                "finish_reason": finish,
            }
            obj_type = "chat.completion.chunk"
        else:
            choice = {"index": 0, "text": text, "finish_reason": finish}
            obj_type = "text_completion"
        if tok is not None:
            choice["token_id"] = int(tok)
            choice["output_index"] = idx
        obj = {"id": f"cmpl-{req.request_id}", "object": obj_type,
               "created": int(time.time()), "model": self.frontend.model,
               "choices": [choice]}
        return b"data: " + json.dumps(obj).encode("utf-8") + b"\n\n"

    def _stream_response(self, req: Request, ch: "_queue.Queue",
                         chat: bool) -> None:
        fe = self.frontend
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        idx = 0
        try:
            while True:
                kind, val = ch.get(timeout=fe.request_timeout_s)
                if kind == "tok":
                    self.wfile.write(self._chunk(req, chat, idx=idx,
                                                 tok=val, finish=None))
                    self.wfile.flush()
                    idx += 1
                else:
                    fe.record(val)   # before the socket: stats never
                    #                  depend on the client reading DONE
                    self.wfile.write(self._chunk(
                        req, chat, idx=idx, tok=None,
                        finish=val.finish_reason or "stop"))
                    self.wfile.write(b"data: [DONE]\n\n")
                    self.wfile.flush()
                    return
        except _queue.Empty:
            # runtime wedged (or died): end the stream; diagnostics live
            # in /healthz
            try:
                self.wfile.write(b"data: [DONE]\n\n")
            except OSError:
                pass
        except (BrokenPipeError, ConnectionResetError):
            # client went away: cancel so the runtime frees KV/slots on
            # every stage node instead of decoding into a dead socket.
            # on_done still fires (finish_reason "cancelled" — or a real
            # finish if the request won the race), so stats record the
            # truncated request either way.
            fe.rt.cancel(req.request_id)
            try:
                while True:
                    kind, val = ch.get(timeout=fe.request_timeout_s)
                    if kind == "done":
                        fe.record(val)
                        return
            except _queue.Empty:
                pass

    def _full_response(self, req: Request, ch: "_queue.Queue",
                       chat: bool) -> None:
        fe = self.frontend
        try:
            while True:
                kind, val = ch.get(timeout=fe.request_timeout_s)
                if kind == "done":
                    break
        except _queue.Empty:
            self._error(504, "request timed out in the runtime")
            return
        fe.record(val)
        text = decode_tokens(req.output)
        if chat:
            choice: Dict[str, Any] = {
                "index": 0,
                "message": {"role": "assistant", "content": text},
                "finish_reason": req.finish_reason,
            }
            obj_type = "chat.completion"
        else:
            choice = {"index": 0, "text": text,
                      "finish_reason": req.finish_reason}
            obj_type = "text_completion"
        choice["token_ids"] = [int(t) for t in req.output]
        self._json(200, {
            "id": f"cmpl-{req.request_id}", "object": obj_type,
            "created": int(time.time()), "model": fe.model,
            "choices": [choice],
            "usage": {"prompt_tokens": int(len(req.prompt)),
                      "completion_tokens": len(req.output),
                      "total_tokens": int(len(req.prompt))
                      + len(req.output)},
        })
