"""Live autoscaling over the serving runtime (Mélange x Helix, online).

The mix planner (``core/mix_planner.py``) answers "which cluster should I
rent for THIS traffic"; the :class:`Autoscaler` keeps asking it as traffic
drifts, and applies the answer to a *running* ``ClusterRuntime`` through
the same replan machinery failover uses (``plan()`` + ``apply_plan``):

  scale-up    measured traffic (front-door arrival rate + completed
              (input, output) length pairs) no longer fits the current
              node mix -> solve the cheapest mix that does, grow the
              ``ClusterSpec`` (never shrinking below what is running),
              re-place, ``apply_plan``.  Engines for the new nodes are
              built by the runtime's engine factory — the ``spawn_workers``
              factory dials up a fresh worker process for a node name it
              has never seen, so scale-up works over sockets too.
  scale-down  the mix stays feasible without some node for
              ``patience`` consecutive ticks -> two-phase drain + retire:
              first shift flow away (``reweight_for_straggler`` with a
              ~zero factor: placement unchanged, IWRR weights move), then
              once the node holds no slots, apply a plan without it.
  straggler   a node's measured wall-seconds/token drifts past
              ``straggler_factor`` x the fleet median -> re-run max flow
              with its capacity degraded by the measured ratio and swap
              IWRR weights in place (``reweight_for_straggler``'s first
              real caller) — no engines rebuilt, no requests requeued.

Thread discipline: the autoscaler samples from its own thread (or from
``tick()`` in tests — fully synchronous, no thread needed) but NEVER
mutates the runtime directly; every mutation rides
``ClusterRuntime.call_soon`` onto the loop thread, the same FIFO a
``cancel()`` rides, so plans apply between steps, never during one.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.cluster import COORDINATOR, ClusterSpec, DeviceProfile, NodeSpec
from ..core.mix_planner import (SLO, ThroughputTable, TrafficProfile,
                                mix_is_feasible, solve_mix)
from ..core.placement import LayerRange, Placement
from ..core.planner import Plan, plan as plan_cluster, reweight_for_straggler


@dataclasses.dataclass
class AutoscaleEvent:
    t: float                       # runtime clock at decision time
    kind: str                      # scale_up | drain | retire | straggler
    detail: str


class Autoscaler:
    """Samples live serving signals, decides, applies — see module docstring.

    Parameters
    ----------
    runtime, plan : the running ``ClusterRuntime`` and the ``Plan`` it was
        built from (the runtime keeps cluster/placement but not the Plan).
    frontend : optional ``Frontend`` — the arrival-rate / length-pair
        source.  Tests may instead inject ``traffic_fn`` returning a
        ``TrafficProfile`` (or None for "no signal yet").
    catalog : device types the autoscaler may rent, name -> profile.
        Defaults to the distinct device types already in the cluster.
    slo, headroom : mix-solver inputs; ``headroom`` over-provisions so a
        marginal drift does not re-trigger every tick.
    patience : consecutive ticks a condition must hold before acting —
        one slow sample must not buy a GPU.
    """

    def __init__(self, runtime, plan: Plan, *, frontend=None,
                 catalog: Optional[Dict[str, DeviceProfile]] = None,
                 slo: SLO = SLO(), headroom: float = 1.2,
                 patience: int = 3, window_s: float = 30.0,
                 hi_occupancy: float = 0.9,
                 straggler_factor: float = 2.0,
                 scale_down_margin: float = 1.5,
                 min_decode_tokens: int = 32,
                 max_nodes: int = 64,
                 prefill_speedup: float = 2.0,
                 traffic_fn: Optional[Callable[[], Optional[TrafficProfile]]]
                 = None,
                 solver: str = "auto"):
        self.rt = runtime
        self.plan = plan
        self.frontend = frontend
        self.slo = slo
        self.headroom = headroom
        self.patience = max(1, patience)
        self.window_s = window_s
        self.hi_occupancy = hi_occupancy
        self.straggler_factor = straggler_factor
        self.scale_down_margin = scale_down_margin
        self.min_decode_tokens = min_decode_tokens
        self.max_nodes = max_nodes
        self.prefill_speedup = prefill_speedup
        self.traffic_fn = traffic_fn
        self.solver = solver
        if catalog is None:
            catalog = {}
            for name, node in runtime.cluster.nodes.items():
                if name != COORDINATOR:
                    catalog.setdefault(node.device.name, node.device)
        self.catalog = catalog
        self.events: List[AutoscaleEvent] = []
        self._over = 0               # consecutive overloaded ticks
        self._under = 0              # consecutive underloaded ticks
        self._slow: Dict[str, int] = {}          # node -> slow-tick streak
        self._reweighted: Dict[str, float] = {}  # node -> applied factor
        self._draining: Optional[str] = None     # node mid drain+retire
        self._node_busy: Dict[str, bool] = {}    # loop-thread probe results
        self._spawned = 0                        # unique-name counter
        self._last_decode: Dict[str, Tuple[float, int]] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if frontend is not None:
            frontend.autoscaler = self

    # -- lifecycle ----------------------------------------------------------
    def start(self, interval_s: float = 5.0) -> None:
        """Sample on a daemon thread every ``interval_s`` until ``stop()``."""
        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.tick()
                except Exception as e:   # a bad tick must not kill sampling
                    self._event("error", repr(e))
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="autoscaler")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def describe(self) -> Dict[str, Any]:
        return {
            "nodes": self._counts(),
            "cost_per_hour": round(self.rt.cluster.cost_per_hour(), 4),
            "draining": self._draining,
            "reweighted": dict(self._reweighted),
            "events": [dataclasses.asdict(e) for e in self.events[-8:]],
            "num_events": len(self.events),
        }

    # -- signal gathering ---------------------------------------------------
    def _counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for name, node in self.rt.cluster.nodes.items():
            if name == COORDINATOR:
                continue
            key = node.device.name
            counts[key] = counts.get(key, 0) + 1
        return counts

    def measure_traffic(self) -> Optional[TrafficProfile]:
        """Bucketed live traffic, from the injected ``traffic_fn`` or the
        front door's arrival window + completed length pairs.  None until
        there is enough signal to bucket (no completions yet)."""
        if self.traffic_fn is not None:
            return self.traffic_fn()
        fe = self.frontend
        if fe is None:
            return None
        rate = fe.arrival_rate(self.window_s)
        with fe._lock:
            pairs = list(fe.lengths)
        if rate <= 0 or not pairs:
            return None
        return TrafficProfile.from_requests(pairs, rate)

    def _table(self, traffic: TrafficProfile) -> ThroughputTable:
        return ThroughputTable.profile(
            self.rt.profile, traffic.buckets, sorted(self.catalog),
            slo=self.slo, devices=self.catalog,
            prefill_speedup=self.prefill_speedup)

    # -- the decision loop --------------------------------------------------
    def tick(self) -> Optional[str]:
        """One sampling + decision pass.  Returns the action taken (or
        None) — synchronous and thread-free, so virtual-clock tests drive
        it directly and assert on the result."""
        self._check_stragglers()
        if self._draining is not None:
            return self._continue_retire()
        traffic = self.measure_traffic()
        if traffic is None or traffic.rate_rps <= 0:
            self._over = self._under = 0
            return None
        table = self._table(traffic)
        want = dataclasses.replace(traffic,
                                   rate_rps=traffic.rate_rps * self.headroom,
                                   weights=list(traffic.weights))
        counts = self._counts()
        occ = self.rt.node_occupancy()
        hot = occ and max(occ.values()) >= self.hi_occupancy
        if not mix_is_feasible(table, want, counts) or hot:
            self._under = 0
            self._over += 1
            if self._over >= self.patience:
                self._over = 0
                return self._scale_up(traffic, table, hot=bool(hot))
            return None
        self._over = 0
        victim = self._retirable(table, traffic, counts)
        if victim is not None:
            self._under += 1
            if self._under >= self.patience:
                self._under = 0
                return self._begin_drain(victim)
        else:
            self._under = 0
        return None

    # -- straggler reweighting ----------------------------------------------
    def _decode_rates(self) -> Dict[str, float]:
        """Wall seconds/token per node since the previous tick (nodes that
        decoded fewer than ``min_decode_tokens`` are skipped — a two-token
        sample must not look like a straggler)."""
        out: Dict[str, float] = {}
        for node in list(self.rt.node_decode_tokens):
            s = self.rt.node_decode_s.get(node, 0.0)
            n = self.rt.node_decode_tokens.get(node, 0)
            ps, pn = self._last_decode.get(node, (0.0, 0))
            self._last_decode[node] = (s, n)
            if n - pn >= self.min_decode_tokens:
                out[node] = (s - ps) / (n - pn)
        return out

    def _check_stragglers(self) -> None:
        rates = self._decode_rates()
        if len(rates) < 2:
            return
        med = sorted(rates.values())[len(rates) // 2]
        if med <= 0:
            return
        for node, spt in rates.items():
            if spt > self.straggler_factor * med:
                self._slow[node] = self._slow.get(node, 0) + 1
            else:
                self._slow.pop(node, None)
                if node in self._reweighted:
                    # recovered: restore full capacity in the flow graph
                    self._apply_reweight(node, 1.0, recovered=True)
            if self._slow.get(node, 0) >= self.patience:
                self._slow[node] = 0
                factor = max(med / spt, 0.05)
                if abs(self._reweighted.get(node, 1.0) - factor) > 0.1:
                    self._apply_reweight(node, factor)

    def _apply_reweight(self, node: str, factor: float,
                        recovered: bool = False) -> None:
        base = self.plan
        if factor >= 1.0 - 1e-9:
            # rebuild flows from the undegraded cluster
            p = plan_cluster(base.cluster, base.model,
                             placement=base.placement)
            self._reweighted.pop(node, None)
        else:
            p = reweight_for_straggler(base, node, factor)
            self._reweighted[node] = factor
        self.plan = p
        self.rt.call_soon(lambda: self.rt.apply_plan(p))
        self._event("straggler",
                    f"{node} {'recovered' if recovered else 'degraded'} "
                    f"factor={factor:.3f}")

    # -- scale-up ------------------------------------------------------------
    def _scale_up(self, traffic: TrafficProfile, table: ThroughputTable,
                  hot: bool) -> Optional[str]:
        counts = self._counts()
        mix = solve_mix(self.rt.profile, traffic, sorted(self.catalog),
                        slo=self.slo, headroom=self.headroom,
                        solver=self.solver, table=table)
        target = {g: max(mix.counts.get(g, 0), counts.get(g, 0))
                  for g in set(mix.counts) | set(counts)}
        add = {g: target[g] - counts.get(g, 0)
               for g in target if target[g] > counts.get(g, 0)}
        if not add and hot:
            # the mix says current capacity suffices but pools are pinned
            # hot (e.g. long contexts, not rate): add one of the cheapest
            # type that can hold at least one layer
            g = min((g for g in self.catalog if table.max_layers[g] > 0),
                    key=lambda g: self.catalog[g].cost_per_hour,
                    default=None)
            if g is None:
                return None
            add = {g: 1}
        if not add:
            return None
        total = sum(counts.values()) + sum(add.values())
        if total > self.max_nodes:
            self._event("error", f"scale_up would exceed max_nodes="
                        f"{self.max_nodes} ({total})")
            return None
        cluster = self.rt.cluster
        new_nodes: List[str] = []
        for g in sorted(add):
            for _ in range(add[g]):
                name = f"{g.lower()}-as{self._spawned}"
                self._spawned += 1
                cluster = cluster.add_node(NodeSpec(name, self.catalog[g]))
                new_nodes.append(name)
        p = self._replan_grown(cluster, new_nodes)
        self.plan = p
        self.rt.call_soon(lambda: self.rt.apply_plan(p))
        self._event("scale_up", f"+{add} -> ${cluster.cost_per_hour():.2f}"
                    f"/hr nodes={sorted(new_nodes)}")
        return "scale_up"

    def _replan_grown(self, cluster: ClusterSpec,
                      new_nodes: List[str]) -> Plan:
        """Place the model on the grown cluster.  Preferred: keep every
        incumbent node's layer range untouched (running requests keep
        their pipelines — nothing requeues) and give the new nodes their
        own proportional pipeline over the full model; fall back to a
        fresh MILP solve when the new nodes cannot cover the model alone."""
        model = self.rt.profile
        old = dict(self.plan.placement.assignment)
        caps = {}
        # role-split (disaggregated) placements need the MILP to assign the
        # new nodes roles; the incumbent-preserving shortcut skips them
        ok = not (self.plan.placement.meta or {}).get("roles")
        for n in new_nodes:
            caps[n] = cluster.nodes[n].device.tokens_per_s(
                1, model.flops_per_token_layer)
            if cluster.max_layers_on(n, model) < 1:
                ok = False
        if ok and new_nodes:
            total = sum(caps.values())
            assign = dict(old)
            start = 0
            order = sorted(new_nodes, key=lambda n: -caps[n])
            for i, n in enumerate(order):
                share = (model.num_layers - start) if i == len(order) - 1 \
                    else max(1, round(model.num_layers * caps[n] / total))
                share = min(share, cluster.max_layers_on(n, model),
                            model.num_layers - start)
                if share > 0:
                    assign[n] = LayerRange(start, start + share)
                    start += share
                if start >= model.num_layers:
                    break
            if start >= model.num_layers:
                p = Placement(assign, model.num_layers,
                              meta=dict(self.plan.placement.meta or {}))
                if not p.validate():
                    return plan_cluster(cluster, model, placement=p)
        return plan_cluster(cluster, model)

    # -- scale-down: drain + retire ------------------------------------------
    def _retirable(self, table: ThroughputTable, traffic: TrafficProfile,
                   counts: Dict[str, int]) -> Optional[str]:
        """Most expensive node whose removal keeps the mix feasible at
        ``scale_down_margin`` x the measured traffic (margin ON TOP of the
        solver headroom, so scale-down hysteresis > scale-up threshold and
        the pair cannot oscillate)."""
        want = dataclasses.replace(
            traffic,
            rate_rps=traffic.rate_rps * self.headroom
            * self.scale_down_margin,
            weights=list(traffic.weights))
        names = [n for n in self.rt.cluster.nodes if n != COORDINATOR]
        if len(names) <= 1:
            return None
        for name in sorted(names, key=lambda n:
                           -self.rt.cluster.nodes[n].cost_per_hour):
            dev = self.rt.cluster.nodes[name].device.name
            if dev not in table.rates:
                continue
            fewer = dict(counts)
            fewer[dev] -= 1
            if mix_is_feasible(table, want, fewer):
                return name
        return None

    def _begin_drain(self, node: str) -> Optional[str]:
        """Phase 1: shift flow off the node (placement unchanged, IWRR
        weights re-derived from a near-zero-capacity flow solve) so new
        requests route elsewhere while residents finish."""
        p = reweight_for_straggler(self.plan, node, 1e-3)
        self.plan = p
        self._draining = node
        self._node_busy[node] = True
        self.rt.call_soon(lambda: self.rt.apply_plan(p))
        self._probe_busy(node)
        self._event("drain", f"{node} draining "
                    f"(${self.rt.cluster.nodes[node].cost_per_hour:.2f}/hr)")
        return "drain"

    def _probe_busy(self, node: str) -> None:
        """Ask the loop thread whether any live job still holds a slot on
        ``node`` — jobs are loop-affine, so the probe rides call_soon."""
        def probe():
            self._node_busy[node] = any(
                node in j.slots for j in self.rt.jobs.values())
        self.rt.call_soon(probe)

    def _continue_retire(self) -> Optional[str]:
        node = self._draining
        if self._node_busy.get(node, True):
            self._probe_busy(node)   # still busy: re-probe, wait
            return None
        # Phase 2: node is empty — remove it and re-place.  Seed with the
        # incumbent assignment minus the node so survivors keep their
        # slices when they still cover the model.
        cluster = self.rt.cluster.remove_node(node)
        surviving = {n: r for n, r
                     in self.plan.placement.assignment.items() if n != node}
        model = self.rt.profile
        p = None
        if surviving:
            seed = Placement(surviving, model.num_layers,
                             meta=dict(self.plan.placement.meta or {}))
            if not seed.validate():
                p = plan_cluster(cluster, model, placement=seed)
        if p is None:
            p = plan_cluster(cluster, model)
        self.plan = p
        self._draining = None
        self._node_busy.pop(node, None)
        self._reweighted.pop(node, None)
        self.rt.call_soon(lambda: self.rt.apply_plan(p))
        self._event("retire", f"{node} retired -> "
                    f"${cluster.cost_per_hour():.2f}/hr")
        return "retire"

    # -- misc ----------------------------------------------------------------
    def _event(self, kind: str, detail: str) -> None:
        self.events.append(AutoscaleEvent(t=self.rt.clock(), kind=kind,
                                          detail=detail))
