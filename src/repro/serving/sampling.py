"""Token sampling: greedy / temperature (numpy-side, per request)."""
from __future__ import annotations

import numpy as np


def sample_token(logits: np.ndarray, temperature: float,
                 rng: np.random.RandomState) -> int:
    logits = np.asarray(logits, np.float64)
    if temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits / max(temperature, 1e-6)
    z -= z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))
