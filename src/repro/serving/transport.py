"""SocketTransport: the real RPC plane behind the ``Transport`` seam.

The ``ClusterRuntime`` moves stage payloads (prompt-token chunks,
activations, sampled tokens) between nodes through ``Transport.send``; the
in-process implementation hands references over a virtual clock.  This
module is the other side of that seam: per-node **stage worker processes**
(``repro.launch.worker``) own the stage engines, and the pieces here move
real bytes to them:

  wire format       ``encode_payload`` / ``decode_payload``: a tagged binary
                    codec for the payload trees the runtime ships — numpy /
                    JAX arrays travel as a dtype/shape header plus their raw
                    buffer (no pickling, no copies of the array body on
                    encode; ``decode_payload`` returns views into the frame),
                    alongside ints, floats, strs, bytes, bools, None, lists,
                    tuples and dicts.  Malformed or truncated input raises
                    ``FrameError`` — never hangs, never guesses.
  frames            ``send_frame`` / ``recv_frame``: length-prefixed TCP
                    frames (8-byte magic+length header).  A peer closing
                    mid-frame raises ``FrameError`` instead of blocking.
  WorkerChannel     one lock-serialized request/response socket to a stage
                    worker; every call gets an ``("ok", result)`` or
                    ``("err", traceback)`` reply.  Socket failures raise
                    ``WorkerDied`` and poison the channel.
  SocketTransport   ``Transport`` over worker channels.  Each (src, dst)
                    link gets a **bounded send queue** drained by its own
                    pump thread: array payloads are staged into the
                    destination worker's memory (the delivery the runtime
                    sees is a ``StagedRef`` the next engine RPC resolves
                    worker-side), scalar payloads round-trip through the
                    codec and deliver by value.  A full queue blocks the
                    sender — backpressure, not unbounded buffering — and
                    raises ``TransportStalled`` naming the link if it stays
                    full past ``send_timeout_s``.  ``describe()`` reports
                    per-link queue depth and stalled transmissions; the
                    runtime appends it to its ``_state()`` diagnostics.
  RemoteStageEngine the coordinator-side proxy speaking the stage-engine
                    API (``prefill_stage`` / ``prefill_chunk`` /
                    ``decode_stage`` / slot + pool bookkeeping) over a
                    ``WorkerChannel``.  Final-stage sampling happens
                    coordinator-side on the logits the decode reply carries.

Nothing here imports the runtime; ``runtime.ClusterRuntime.spawn_workers``
wires these pieces to worker processes it launches (or to externally
started ``python -m repro.launch.worker --connect host:port`` workers).
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import socket
import struct
import sys
import threading
import time
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

try:                                    # registers bfloat16/float8 etc. with
    import ml_dtypes  # noqa: F401     # numpy's dtype registry
except ImportError:                     # pragma: no cover - jax ships it
    pass

from ..core.cluster import COORDINATOR
from .sampling import sample_token
from .stage_engine import DecodeItem, DecodeOut


class FrameError(ValueError):
    """Malformed, truncated, or unreadable wire data."""


class WorkerDied(RuntimeError):
    """The socket to a stage worker failed (process killed, link down)."""


class WorkerError(RuntimeError):
    """The worker received the call but raised executing it."""


class TransportStalled(RuntimeError):
    """A bounded per-link send queue stayed full past the send timeout."""


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

_MAGIC = b"HLXF"
_HEADER = struct.Struct("!4sI")
MAX_FRAME_BYTES = 1 << 31               # anything larger is a corrupt header


@dataclasses.dataclass(frozen=True)
class StagedRef:
    """Handle to a payload already staged in a worker's memory: the
    transport ships the bytes once, the next engine RPC resolves the tag."""

    tag: int


_I32 = struct.Struct("!i")
_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")
_U32 = struct.Struct("!I")
_U64 = struct.Struct("!Q")


def encode_payload(obj: Any) -> List[Any]:
    """Encode a payload tree into a list of buffer segments (bytes /
    memoryview).  Array bodies are appended as memoryviews of the original
    buffer — zero-copy for C-contiguous arrays."""
    parts: List[Any] = []
    _enc(obj, parts)
    return parts


def payload_bytes(obj: Any) -> bytes:
    return b"".join(bytes(p) for p in encode_payload(obj))


def _enc(obj: Any, parts: List[Any]) -> None:
    if obj is None:
        parts.append(b"N")
    elif obj is True:
        parts.append(b"T")
    elif obj is False:
        parts.append(b"F")
    elif isinstance(obj, (int, np.integer)):
        try:
            parts.append(b"i" + _I64.pack(int(obj)))
        except struct.error:
            raise FrameError(f"int {obj} outside the int64 wire range") \
                from None
    elif isinstance(obj, (float, np.floating)):
        parts.append(b"f" + _F64.pack(float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        parts.append(b"s" + _U32.pack(len(raw)) + raw)
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        parts.append(b"b" + _U32.pack(len(obj)))
        parts.append(bytes(obj))
    elif isinstance(obj, StagedRef):
        parts.append(b"r" + _U64.pack(obj.tag))
    elif isinstance(obj, list):
        parts.append(b"l" + _U32.pack(len(obj)))
        for it in obj:
            _enc(it, parts)
    elif isinstance(obj, tuple):
        parts.append(b"t" + _U32.pack(len(obj)))
        for it in obj:
            _enc(it, parts)
    elif isinstance(obj, dict):
        parts.append(b"d" + _U32.pack(len(obj)))
        for k, v in obj.items():
            _enc(k, parts)
            _enc(v, parts)
    elif isinstance(obj, np.bool_):
        parts.append(b"T" if obj else b"F")
    elif isinstance(obj, np.ndarray) or hasattr(obj, "__array__"):
        arr = np.asarray(obj)
        if arr.dtype.byteorder == ">" or (arr.dtype.byteorder == "="
                                          and sys.byteorder == "big"):
            # the wire is little-endian; dtype.name drops byte order, so a
            # big-endian buffer must be swapped, not silently reinterpreted
            arr = arr.astype(arr.dtype.newbyteorder("<"))
        if not arr.flags["C_CONTIGUOUS"]:
            # NB ascontiguousarray would also promote 0-d to 1-d, so only
            # copy when the layout actually requires it
            arr = np.ascontiguousarray(arr)
        name = arr.dtype.name.encode("ascii")
        head = (b"a" + _U32.pack(len(name)) + name
                + struct.pack("!B", arr.ndim))
        for dim in arr.shape:
            head += _U64.pack(dim)
        head += _U64.pack(arr.nbytes)
        parts.append(head)
        parts.append(arr.reshape(-1).view(np.uint8).data)  # zero-copy view
    else:
        raise FrameError(f"unserializable payload type {type(obj).__name__}")


class _Reader:
    """Bounds-checked cursor over a frame body: running past the end (a
    truncated frame) raises FrameError instead of returning garbage."""

    def __init__(self, data):
        self.view = memoryview(data)
        self.pos = 0

    def take(self, n: int) -> memoryview:
        if n < 0 or self.pos + n > len(self.view):
            raise FrameError(
                f"truncated frame: wanted {n} bytes at offset {self.pos}, "
                f"frame holds {len(self.view)}")
        out = self.view[self.pos:self.pos + n]
        self.pos += n
        return out

    def unpack(self, st: struct.Struct):
        return st.unpack(self.take(st.size))[0]


def decode_payload(data) -> Any:
    """Decode one payload tree; raises FrameError on malformed/truncated
    input or trailing garbage.  Arrays are zero-copy views into ``data``
    (read-only when ``data`` is bytes)."""
    r = _Reader(data)
    out = _dec(r)
    if r.pos != len(r.view):
        raise FrameError(f"{len(r.view) - r.pos} trailing bytes after "
                         "payload")
    return out


def _dec(r: _Reader) -> Any:
    tag = bytes(r.take(1))
    if tag == b"N":
        return None
    if tag == b"T":
        return True
    if tag == b"F":
        return False
    if tag == b"i":
        return r.unpack(_I64)
    if tag == b"f":
        return r.unpack(_F64)
    if tag == b"s":
        return bytes(r.take(r.unpack(_U32))).decode("utf-8")
    if tag == b"b":
        return bytes(r.take(r.unpack(_U32)))
    if tag == b"r":
        return StagedRef(r.unpack(_U64))
    if tag in (b"l", b"t"):
        n = r.unpack(_U32)
        items = [_dec(r) for _ in range(n)]
        return items if tag == b"l" else tuple(items)
    if tag == b"d":
        n = r.unpack(_U32)
        return {_dec(r): _dec(r) for _ in range(n)}
    if tag == b"a":
        name = bytes(r.take(r.unpack(_U32))).decode("ascii")
        try:
            dtype = np.dtype(name)
        except TypeError as e:
            raise FrameError(f"unknown dtype {name!r}") from e
        if dtype.byteorder == "=" and sys.byteorder == "big":
            dtype = dtype.newbyteorder("<")    # wire bytes are little-endian
        ndim = r.unpack(struct.Struct("!B"))
        shape = tuple(r.unpack(_U64) for _ in range(ndim))
        nbytes = r.unpack(_U64)
        expect = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if nbytes != expect:
            raise FrameError(f"array header inconsistent: shape {shape} x "
                             f"{dtype} needs {expect} bytes, frame says "
                             f"{nbytes}")
        body = r.take(nbytes)
        return np.frombuffer(body, dtype=dtype).reshape(shape)
    raise FrameError(f"unknown payload tag {tag!r}")


# ---------------------------------------------------------------------------
# frames
# ---------------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> memoryview:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise FrameError(
                f"connection closed mid-frame ({got}/{n} bytes)")
        got += r
    return memoryview(buf)


def send_frame(sock: socket.socket, parts: List[Any]) -> int:
    """Write one length-prefixed frame; returns the body size."""
    total = sum(len(p) for p in parts)
    if total > MAX_FRAME_BYTES:
        raise FrameError(f"frame of {total} bytes exceeds the "
                         f"{MAX_FRAME_BYTES}-byte cap")
    sock.sendall(_HEADER.pack(_MAGIC, total))
    for p in parts:
        sock.sendall(p)
    return total


def recv_frame(sock: socket.socket) -> memoryview:
    """Read one frame body.  Raises FrameError on a bad magic, an oversized
    length, or a peer that closed mid-frame — a torn frame can never make
    the reader hang or mis-sync."""
    head = _recv_exact(sock, _HEADER.size)
    magic, length = _HEADER.unpack(head)
    if magic != _MAGIC:
        raise FrameError(f"bad frame magic {bytes(magic)!r}")
    if length > MAX_FRAME_BYTES:
        raise FrameError(f"frame length {length} exceeds the "
                         f"{MAX_FRAME_BYTES}-byte cap")
    return _recv_exact(sock, length)


# ---------------------------------------------------------------------------
# worker channel (RPC)
# ---------------------------------------------------------------------------

class WorkerChannel:
    """One request/response socket to a stage worker.  ``call`` is
    lock-serialized: the runtime thread (engine RPCs) and the transport pump
    threads (payload staging) share it safely."""

    def __init__(self, sock: socket.socket, node: str = "?",
                 timeout_s: float = 300.0):
        sock.settimeout(timeout_s)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass                        # socketpairs have no TCP options
        self.sock = sock
        self.node = node
        self._lock = threading.Lock()
        self._dead: Optional[str] = None

    @property
    def alive(self) -> bool:
        return self._dead is None

    def call(self, method: str, *args):
        with self._lock:
            if self._dead is not None:
                raise WorkerDied(f"worker {self.node} is down: {self._dead}")
            try:
                send_frame(self.sock, encode_payload((method, list(args))))
                reply = decode_payload(recv_frame(self.sock))
            except (OSError, FrameError) as e:
                self._dead = repr(e)
                raise WorkerDied(
                    f"worker {self.node} died during {method!r}: {e}") from e
        status, value = reply
        if status != "ok":
            raise WorkerError(f"worker {self.node} failed {method!r}: "
                              f"{value}")
        return value

    def close(self) -> None:
        if self._dead is None:
            self._dead = "closed"
        try:
            self.sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# socket transport
# ---------------------------------------------------------------------------

def _is_scalar(payload: Any) -> bool:
    """Scalar control payloads (sampled tokens and (index, token) pairs)
    deliver by value — staging a single int in a worker would be a wasted
    round trip; the value rides the next engine RPC instead."""
    if isinstance(payload, (bool, int, float, np.integer, np.floating)):
        return True
    if isinstance(payload, tuple):
        return all(_is_scalar(p) for p in payload)
    return False


class SocketTransport:
    """Real-byte transport over per-worker channels (see module docstring).

    ``realtime = True`` tells the runtime to run its event loop on the wall
    clock (deliveries arrive through a thread-safe mailbox) instead of the
    virtual clock the in-process transport uses.

    ``direct_links`` marks the routed worker-to-worker topology: stage
    workers hold peer channels and forward activation frames directly
    (``launch.worker``), so a stage->stage ``send`` arrives carrying a
    ``StagedRef`` whose bytes are *already* at the destination — the
    transport just counts the (src, dst) hop and delivers the ref.  In the
    default star topology every stage->stage payload physically rides the
    RPC reply back to the coordinator and is then staged to the next
    worker; the hop/byte counters charge that honestly as (src,
    coordinator) + (coordinator, dst), so ``describe()`` exposes the 2k ->
    k per-pass reduction instead of asserting it.
    """

    realtime = True

    def __init__(self, channels: Optional[Dict[str, WorkerChannel]] = None,
                 *, queue_depth: int = 8, send_timeout_s: float = 60.0,
                 stalled_after_s: float = 0.2, direct_links: bool = False):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.channels: Dict[str, WorkerChannel] = dict(channels or {})
        self.queue_depth = queue_depth
        self.send_timeout_s = send_timeout_s
        self.stalled_after_s = stalled_after_s
        self.direct_links = direct_links
        self.transfers: Dict[Tuple[str, str], int] = defaultdict(int)
        self.bytes_sent: Dict[Tuple[str, str], int] = defaultdict(int)
        self.dead: set = set()
        # runtime-maintained one-liners appended to describe() (e.g. the
        # speculation counters, shown next to the hop/byte counters)
        self.annotations: Dict[str, str] = {}
        self._queues: Dict[Tuple[str, str], queue.Queue] = {}
        self._busy_since: Dict[Tuple[str, str], float] = {}
        self._tags = itertools.count(1)
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._schedule: Callable[[float, Callable[[], None]], None] = \
            lambda d, fn: fn()

    def bind(self, schedule: Callable[[float, Callable[[], None]], None]
             ) -> None:
        """The runtime binds a thread-safe scheduler (mailbox put)."""
        self._schedule = schedule

    def alloc_tag(self) -> int:
        """Allocate a staging tag (shared counter with the pump path, so a
        worker-side forward can never collide with a pump-staged payload
        in the destination worker's stash)."""
        return next(self._tags)

    # -- sending ---------------------------------------------------------
    def send(self, src: str, dst: str, payload: Any, nbytes: float,
             deliver: Callable[[Any], None]) -> None:
        if self._stop.is_set():
            return
        if isinstance(payload, StagedRef):
            # routed path: the source worker already pushed the bytes to
            # the destination worker's staging area over a peer channel
            # (and acked) before its RPC replied — one physical (src, dst)
            # hop, nothing left to move here
            self.transfers[(src, dst)] += 1
            self.bytes_sent[(src, dst)] += int(nbytes)
            self._schedule(0.0, lambda p=payload: deliver(p))
            return
        if src != COORDINATOR and dst != COORDINATOR:
            # star path: the payload reached the coordinator as an RPC
            # reply and is re-sent below — charge both physical hops
            self.transfers[(src, COORDINATOR)] += 1
            self.bytes_sent[(src, COORDINATOR)] += int(nbytes)
            self.transfers[(COORDINATOR, dst)] += 1
            self.bytes_sent[(COORDINATOR, dst)] += int(nbytes)
        else:
            self.transfers[(src, dst)] += 1
            self.bytes_sent[(src, dst)] += int(nbytes)
        link = (src, dst)
        q = self._link_queue(link)
        try:
            # bounded: a slow receiver blocks the sender here instead of
            # growing an unbounded buffer
            q.put((payload, deliver), timeout=self.send_timeout_s)
        except queue.Full:
            raise TransportStalled(
                f"link {src}->{dst}: send queue full ({self.queue_depth} "
                f"deep) for {self.send_timeout_s:.1f}s — receiver not "
                f"draining; {self.describe()}") from None

    def _link_queue(self, link: Tuple[str, str]) -> queue.Queue:
        with self._lock:
            q = self._queues.get(link)
            if q is None:
                q = queue.Queue(maxsize=self.queue_depth)
                self._queues[link] = q
                t = threading.Thread(target=self._pump, args=(link, q),
                                     name=f"transport-{link[0]}-{link[1]}",
                                     daemon=True)
                t.start()
            return q

    def _pump(self, link: Tuple[str, str], q: queue.Queue) -> None:
        _, dst = link
        while not self._stop.is_set():
            try:
                payload, deliver = q.get(timeout=0.2)
            except queue.Empty:
                continue
            self._busy_since[link] = time.monotonic()
            try:
                ch = self.channels.get(dst)
                if ch is None or _is_scalar(payload):
                    # coordinator-bound or scalar payload: round-trip
                    # through the codec (honest wire semantics), deliver
                    # by value
                    out = decode_payload(payload_bytes(payload))
                    self._schedule(0.0, lambda o=out, dv=deliver: dv(o))
                else:
                    # stage the bytes in the destination worker; the next
                    # engine RPC resolves the ref worker-side
                    tag = next(self._tags)
                    ch.call("stage", tag, payload)
                    self._schedule(
                        0.0, lambda rf=StagedRef(tag), dv=deliver: dv(rf))
            except (WorkerDied, WorkerError, OSError):
                # receiver gone: drop — the runtime's failover requeues the
                # affected requests and their epochs kill stale deliveries
                self.dead.add(dst)
            finally:
                self._busy_since.pop(link, None)
                q.task_done()

    # -- diagnostics -----------------------------------------------------
    def pending(self) -> int:
        busy = len(self._busy_since)
        return sum(q.qsize() for q in self._queues.values()) + busy

    def describe(self) -> str:
        now = time.monotonic()
        frags = []
        for link, q in sorted(self._queues.items()):
            since = self._busy_since.get(link)
            stalled = ""
            if since is not None and now - since > self.stalled_after_s:
                stalled = f" STALLED {now - since:.1f}s"
            if q.qsize() or stalled:
                frags.append(f"{link[0]}->{link[1]} queued={q.qsize()}"
                             f"{stalled}")
        dead = f" dead={sorted(self.dead)}" if self.dead else ""
        mode = "direct" if self.direct_links else "star"
        hops = ", ".join(
            f"{s}->{d}={n}/{self.bytes_sent[(s, d)]}B"
            for (s, d), n in sorted(self.transfers.items()))
        extra = "".join(f" {v}" for _, v in sorted(self.annotations.items()))
        return ("links[" + ", ".join(frags) + "]" + dead
                + f" hops[{mode}: {hops}]" + extra)

    def close(self) -> None:
        self._stop.set()
        for ch in self.channels.values():
            ch.close()


# ---------------------------------------------------------------------------
# remote stage engine (coordinator-side proxy)
# ---------------------------------------------------------------------------

class RemoteStageEngine:
    """Stage-engine API over a WorkerChannel.  The worker owns the params,
    caches and page pool; this proxy owns only the final-stage sampling RNG
    (greedy/temperature sampling runs coordinator-side on the logits the
    decode reply carries, so one RNG stream drives the pipeline exactly as
    a local engine's would).

    ``forward_capable``: compute RPCs accept a forward spec ``fwd=(dst
    node, staging tag)``.  The worker then pushes the stage output straight
    to the destination worker's staging area over a peer channel *before*
    replying, and the proxy returns a ``StagedRef(tag)`` in place of the
    payload — the runtime ships that ref through ``Transport.send``, which
    recognizes it as an already-moved frame (one physical hop, counted on
    the (src, dst) link)."""

    forward_capable = True

    def __init__(self, channel: WorkerChannel, node: str, *,
                 rng_seed: int = 0):
        self.channel = channel
        self.node = node
        self._rng = np.random.RandomState(rng_seed)

    # -- slots / pool ----------------------------------------------------
    def alloc_slot(self, request_id: int) -> Optional[int]:
        return self.channel.call("alloc_slot", request_id)

    def free_slot(self, slot: int) -> None:
        self.channel.call("free_slot", slot)

    def ensure(self, slot: int, tokens: int) -> bool:
        return self.channel.call("ensure", slot, tokens)

    def release(self, slot: int) -> None:
        self.channel.call("release", slot)

    def kv_tokens_used(self) -> int:
        return self.channel.call("kv_tokens_used")

    def kv_tokens_capacity(self) -> int:
        return self.channel.call("kv_tokens_capacity")

    def pool_used(self) -> Optional[int]:
        return self.channel.call("pool_used")

    def pool_num_pages(self) -> Optional[int]:
        return self.channel.call("pool_num_pages")

    # -- compute ---------------------------------------------------------
    def prefill_stage(self, slot: int, x, entry: int,
                      fwd: Optional[Tuple[str, int]] = None):
        out = self.channel.call("prefill_stage", slot, x, entry, fwd)
        return StagedRef(fwd[1]) if fwd is not None else out

    def prefill_chunk(self, slot: int, x, entry: int, start: int,
                      fwd: Optional[Tuple[str, int]] = None):
        out = self.channel.call("prefill_chunk", slot, x, entry, start, fwd)
        return StagedRef(fwd[1]) if fwd is not None else out

    def decode_stage(self, items: List[DecodeItem],
                     fwds: Optional[List[Optional[Tuple[str, int]]]] = None
                     ) -> List[DecodeOut]:
        # 6-tuple wire format: ``tokens`` (a verify pass's token vector, or
        # None) rides last so old captures stay readable; the worker
        # resolves StagedRefs in both ``h`` and ``tokens``
        wire = [(it.slot, it.pos, it.entry, it.token, it.h, it.tokens)
                for it in items]
        outs = self.channel.call("decode_stage", wire,
                                 list(fwds) if fwds else None)
        res = []
        for i, (h, logits) in enumerate(outs):
            if fwds and fwds[i] is not None:
                h = StagedRef(fwds[i][1])
            res.append(DecodeOut(h=h, logits=logits))
        return res

    def rollback(self, slot: int, tokens: int) -> None:
        """Synchronous KV rollback after a rejected speculative verify —
        returns once the worker's pool has truncated (and, for int8,
        restored) the slot, so the relaunch cannot race the rollback."""
        self.channel.call("rollback", slot, tokens)

    # -- KV handoff (disaggregated prefill -> decode) --------------------
    def export_kv(self, slot: int, tokens: int, layers: List[int],
                  fwd: Optional[Tuple[str, int]] = None):
        out = self.channel.call("export_kv", slot, tokens, list(layers), fwd)
        return StagedRef(fwd[1]) if fwd is not None else out

    def import_kv(self, slot: int, tokens: int, payload) -> None:
        self.channel.call("import_kv", slot, tokens, payload)

    def sample(self, logits, temperature: float) -> int:
        return int(sample_token(np.asarray(logits), temperature, self._rng))

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        try:
            if self.channel.alive:
                self.channel.call("shutdown")
        except (WorkerDied, WorkerError):
            pass
        self.channel.close()
