"""ClusterRuntime: execute IWRR pipelines across per-node stage engines.

This is the execution plane the paper's runtime scheduling (§4) assumes: the
MILP places layer slices on nodes, max-flow IWRR walks per-request pipelines,
and *this* module actually runs them — each node owns a stage engine over its
assigned ``LayerRange``, activations hop between nodes through a pluggable
``Transport``, and every node continuously batches whatever stage-work (from
any request, entering at any layer) is resident each iteration.

Event loop: a virtual-clock heap of deliveries.  Prefill hops execute inline
as they arrive (per-request; chunked across stages for all-paged stacks);
decode inputs accumulate in per-node inboxes and run as batched
``decode_stage`` calls per node per iteration — per-node continuous batching.

Pipelined decode (the steady state the paper's max-flow bound §4 assumes):
each request carries an in-flight window of up to ``max_inflight`` decode
passes that are launched but not yet confirmed by the coordinator.  After
sampling token t, the *final stage* speculatively launches the pass for
token t+1 straight to stage 0 — one hop instead of the two-hop
final->coordinator->stage-0 round trip — while token t travels back.  The
coordinator confirms tokens strictly in order (out-of-order arrivals are
buffered per request), applies the stop rules (eos / max_new_tokens /
max_len), and cancels any speculative in-flight passes on completion,
preemption, or failover by bumping the job epoch, which every in-flight
delivery checks.  Launching reserves KV for the new position on *every*
stage node up front, so a mid-pipeline token never lands on an exhausted
pool.  Decode stays autoregressive: pass t+1 exists only once pass t left
the final stage, so a single pass per request is ever inside the stages and
token t+1 always attends to token t's cache write (the stage engine rejects
duplicate-slot batches as the invariant check).  ``max_inflight=1``
degenerates to the classic one-outstanding-token walk (final stage waits
for the coordinator).

Memory: admission takes a slot (and, paged, the prompt's pages) on *every*
stage node up front; completion and preemption release KV on every node of
the pipeline.  When a pool runs dry mid-decode the newest resident request is
preempted pipeline-wide (recompute-on-readmit keeps its generated tokens).

Scheduler feedback: after every iteration the runtime writes each node's true
pool occupancy into the scheduler's ``KVEstimator`` (``sync``), and installs
real pool capacities at startup — IWRR masking reflects actual paged usage
rather than arrival-time reservations drifting from reality.

Routing: every admitted job carries a ``Route`` (prefill pipeline, decode
pipeline, KV handoffs).  With ``transport.direct_links`` the runtime passes
forward specs (``fwd=(dst_node, tag)``) to forward-capable engines, so stage
workers push activation frames straight to the next stage's worker and only
a ``StagedRef`` (and sampled tokens) return to the coordinator — k+1
transport hops per decode token instead of the star topology's 2k.  Both
transports keep per-(src, dst) hop/byte counters surfaced via ``describe()``.

Disaggregation: a placement whose ``meta["roles"]`` tags nodes
prefill/decode/mixed splits into per-role sub-placements, each re-planned
with max-flow into its own scheduler; prompt passes run on the prefill
replica, then each prefill stage's KV (paged: gathered pages + int8 scales,
verbatim) ships to the decode nodes that need it (``export_kv`` →
``import_kv``, over peer links when available).  Decode launches gate on
``kv_pending`` draining; prefill-only slots are released once their
handoffs land.

Speculative decoding (``draft_cfg``/``spec_tokens``): a small draft model
sharing the target's vocab lives AT the coordinator (a dense full-model
``StageEngine``).  Each round the draft proposes γ tokens autoregressively;
the target verifies all γ+1 positions in ONE pass through the decode
pipeline (the stage engines run it as position-ordered sub-batches, so the
KV write history — including int8 page requantization — is byte-identical
to γ+1 ordinary decode steps).  The final stage returns the greedy argmax
vector; the coordinator accepts the longest matching draft prefix, confirms
those tokens in order (plus the bonus token at full acceptance), and on the
first mismatch bumps the job epoch (extending the PR 4 ``cancelled_inflight``
path — straggling duplicates of the dead pass cannot decode after the
rollback) and synchronously rolls every decode stage node back to the
accepted prefix (``rollback`` RPC: page-frontier truncation + int8 frontier-
page restore).  Greedy speculative output is byte-identical to
non-speculative greedy for ANY draft — acceptance rate only changes speed.
Speculation requires ``temperature <= 0``; other requests (and requests
that find the draft's slots full) serve non-speculatively.  Spec jobs keep
exactly one verify pass in flight and launch only from the coordinator
(the draft lives there), so they compose with ``max_inflight`` windows,
disaggregated prefill (launches stay gated on ``kv_pending``) and failover
unchanged.

Failover: ``fail_node`` drops a node's engine and requeues every in-flight
request whose route crossed it; after the planner replans, ``apply_plan``
rebuilds engines whose slices changed, swaps IWRR weights
(``update_weights`` when the placement survived, a fresh scheduler
otherwise), and the requeued requests re-prefill (prompt + generated
tokens) on fresh routes.  A role-less replacement plan drops the runtime
back to mixed (one unified scheduler, no handoffs).
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import queue as _queue
import socket as _socket
import subprocess
import sys
import threading
import time
from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..configs.base import ModelConfig
from ..core.cluster import COORDINATOR
from ..core.placement import LayerRange
from ..models.paged import all_blocks_paged
from ..models.stage import stage_num_paged_layers
from .engine import EngineConfig, Request
from .kv_pool import full_rectangle_pages, pages_for_vram
from .stage_engine import (DecodeItem, PagedStageEngine, StageEngine,
                           make_stage_engine)
from .transport import (RemoteStageEngine, SocketTransport, WorkerChannel,
                        WorkerDied)


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------

class Transport:
    """Moves stage payloads (activations / token ids) between nodes.

    ``send`` must eventually call ``deliver(payload)``; implementations may
    move real bytes (RPC) or just model the delay.  The runtime binds
    ``schedule(delay_s, fn)`` at construction so in-process transports can
    put deliveries on the runtime's virtual clock.
    """

    def bind(self, schedule: Callable[[float, Callable[[], None]], None]
             ) -> None:
        self._schedule = schedule

    def send(self, src: str, dst: str, payload: Any, nbytes: float,
             deliver: Callable[[Any], None]) -> None:
        raise NotImplementedError


class InProcessTransport(Transport):
    """Same-process transport: payloads are handed over by reference after an
    optional modelled link delay (latency + nbytes/bandwidth).  This is the
    seam a real RPC transport plugs into later.

    ``direct_links`` models the routed worker-to-worker topology (the
    default): a stage->stage send costs one (src, dst) hop.  With
    ``direct_links=False`` the transport models the legacy coordinator-
    mediated star: every stage->stage send is charged as TWO physical hops
    — (src, COORDINATOR) then (COORDINATOR, dst) — with both link delays
    paid back to back, exactly the round trip a reply-driven socket run
    pays when the activation returns as the RPC reply before being staged
    to the next worker.  Hop and byte counters reflect the physical route
    either way, so the 2k -> k per-pass reduction is measurable."""

    def __init__(self, default_delay_s: float = 0.0,
                 link_delay_s: Optional[Mapping[Tuple[str, str], float]] = None,
                 bandwidth_bytes_per_s: float = 0.0, *,
                 direct_links: bool = True):
        self.default_delay_s = default_delay_s
        self.link_delay_s = dict(link_delay_s or {})
        self.bandwidth = bandwidth_bytes_per_s
        self.direct_links = direct_links
        self.transfers: Dict[Tuple[str, str], int] = defaultdict(int)
        self.bytes_sent: Dict[Tuple[str, str], float] = defaultdict(float)
        # runtime-maintained one-liners appended to describe() (e.g. the
        # speculation counters, shown next to the hop/byte counters)
        self.annotations: Dict[str, str] = {}

    def delay(self, src: str, dst: str, nbytes: float) -> float:
        d = self.link_delay_s.get((src, dst), self.default_delay_s)
        if self.bandwidth > 0:
            d += nbytes / self.bandwidth
        return d

    def _count(self, src: str, dst: str, nbytes: float) -> None:
        self.transfers[(src, dst)] += 1
        self.bytes_sent[(src, dst)] += nbytes

    def send(self, src: str, dst: str, payload: Any, nbytes: float,
             deliver: Callable[[Any], None]) -> None:
        if (self.direct_links or src == COORDINATOR or dst == COORDINATOR):
            self._count(src, dst, nbytes)
            self._schedule(self.delay(src, dst, nbytes),
                           lambda: deliver(payload))
            return
        # star route: src -> coordinator (RPC reply) -> dst (staging)
        self._count(src, COORDINATOR, nbytes)
        self._count(COORDINATOR, dst, nbytes)
        d = (self.delay(src, COORDINATOR, nbytes)
             + self.delay(COORDINATOR, dst, nbytes))
        self._schedule(d, lambda: deliver(payload))

    def describe(self) -> str:
        frags = [f"{s}->{d}={n}/{self.bytes_sent[(s, d)]:.0f}B"
                 for (s, d), n in sorted(self.transfers.items())]
        mode = "direct" if self.direct_links else "star"
        extra = "".join(f" {v}" for _, v in
                        sorted(getattr(self, "annotations", {}).items()))
        return f"hops[{mode}: " + ", ".join(frags) + "]" + extra


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Route:
    """Per-job compiled dataflow: which nodes run the prompt pass, which
    run decode passes, and which KV handoffs bridge the two replica groups.
    For plain (non-disaggregated) placements prefill and decode are the
    same pipeline and there are no handoffs.  Routes are compiled at every
    (re)admission, so failover replans rebuild them for free.

    ``handoffs`` maps a prefill stage index to the ``(decode node, global
    layers)`` exports due once that stage's final prompt chunk lands —
    layers are matched by global index, so any pair of prefill/decode
    layer splits composes."""

    prefill: Any                      # RequestPipeline for prompt passes
    decode: Any                       # RequestPipeline for decode passes
    handoffs: Dict[int, List[Tuple[str, List[int]]]] = \
        dataclasses.field(default_factory=dict)

    @property
    def disaggregated(self) -> bool:
        return self.prefill is not self.decode

    @property
    def nodes(self) -> set:
        return ({st.node for st in self.prefill.stages}
                | {st.node for st in self.decode.stages})


@dataclasses.dataclass
class _Job:
    req: Request
    pipe: Any = None                 # decode RequestPipeline (== route.decode)
    route: Optional[Route] = None    # compiled dataflow (kept across preempt)
    kv_pending: set = dataclasses.field(default_factory=set)
                                     # (prefill stage idx, decode node) KV
                                     # handoffs not yet imported: decode
                                     # cannot launch until this empties
    slots: Dict[str, int] = dataclasses.field(default_factory=dict)
    pos: int = 0                     # tokens confirmed resident in caches
    epoch: int = 0                   # bumped on preempt/requeue/complete:
                                     # stale in-flight messages die
    seq: int = -1                    # admission order (preemption victims)
    # -- in-flight decode window (reset on every (re)admission) ----------
    next_j: int = 0                  # output index the next launched pass
                                     # will produce
    next_pos: int = 0                # cache position of the next pass
    inbox: Dict[int, int] = dataclasses.field(default_factory=dict)
                                     # out-of-order sampled tokens by index
    # -- delivery hardening (a Transport may duplicate or reorder) -------
    seen: set = dataclasses.field(default_factory=set)
                                     # dedup keys of deliveries already run
    # -- speculative decoding (draft-model) ------------------------------
    draft_slot: Optional[int] = None  # coordinator draft-engine slot
    draft_pos: int = 0               # next draft row to feed (rows below
                                     # hold tokens the draft has consumed)
    spec_drafts: List[int] = dataclasses.field(default_factory=list)
                                     # γ proposals of the in-flight verify
    spec_base: int = 0               # cache position of the verify pass
    hop_next: Dict[int, int] = dataclasses.field(default_factory=dict)
                                     # per-stage next expected chunk offset
    hop_stash: Dict[int, Dict[int, Any]] = dataclasses.field(
        default_factory=dict)        # reordered chunks awaiting predecessors

    @property
    def resumed(self) -> bool:
        return bool(self.req.output)

    @property
    def inflight(self) -> int:
        """Decode passes launched whose token the coordinator has not yet
        confirmed (includes sampled tokens still travelling back)."""
        return self.next_j - len(self.req.output)


class ClusterRuntime:
    """Orchestrates one stage engine per placed node (see module docstring).

    ``plan`` is a ``repro.core.planner.Plan``; engines are built from its
    placement, with paged pools sized from each node's own VRAM (capped at
    the full rectangle, floored at one max_len request).
    """

    def __init__(self, cfg: ModelConfig, params, plan, engine_cfg: EngineConfig,
                 *, paged: bool = True, page_size: int = 16,
                 kv_dtype: Optional[str] = None,
                 pool_pages: Optional[Mapping[str, int]] = None,
                 transport: Optional[Transport] = None,
                 interpret: Optional[bool] = None, rng_seed: int = 0,
                 max_inflight: int = 1,
                 engine_factory: Optional[Callable[["ClusterRuntime", str,
                                                    LayerRange], Any]] = None,
                 stall_timeout_s: float = 60.0,
                 draft_cfg: Optional[ModelConfig] = None, draft_params=None,
                 spec_tokens: int = 4,
                 realtime: Optional[bool] = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.paged = paged
        self.max_inflight = max_inflight
        self.page_size = page_size
        self.kv_dtype = kv_dtype
        self.pool_pages = dict(pool_pages or {})
        self.interpret = interpret
        self.rng_seed = rng_seed
        self.stall_timeout_s = stall_timeout_s
        self._engine_factory = engine_factory
        self.cluster = plan.cluster
        self.placement = plan.placement
        self.profile = plan.model
        if plan.model.num_layers != cfg.num_layers:
            raise ValueError(f"plan covers {plan.model.num_layers} layers; "
                             f"{cfg.name} has {cfg.num_layers}")
        self._build_role_schedulers(plan)
        self.transport = transport or InProcessTransport()
        # realtime transports (sockets) finish deliveries on their own
        # threads: they get a thread-safe mailbox drained by step(), and the
        # loop runs on the wall clock.  Virtual-clock transports keep the
        # deterministic event heap, unless ``realtime=True`` forces the wall
        # clock (the online front door over an in-process transport), in
        # which case modelled link delays become real timers feeding the
        # same mailbox.
        auto = bool(getattr(self.transport, "realtime", False))
        self.realtime = auto if realtime is None else bool(realtime)
        self._mailbox: "_queue.Queue" = _queue.Queue()
        self._ingest: "_queue.Queue" = _queue.Queue()
        # jobs (not control messages) sitting in _ingest: cancel markers and
        # call_soon thunks ride the same FIFO, so qsize() would overcount
        # pending work — this counter tracks real submissions only
        self._ingest_jobs = 0
        self._ingest_lock = threading.Lock()
        self._listeners: Dict[int, Tuple[Optional[Callable[[int], None]],
                                         Optional[Callable[[Request], None]]]
                              ] = {}
        self._stop_serving = threading.Event()
        self._t0 = time.monotonic()
        if auto:
            self.transport.bind(lambda d, fn: self._mailbox.put(fn))
        elif self.realtime:
            self.transport.bind(self._deliver_realtime)
        else:
            self.transport.bind(lambda d, fn: self._push(self._now + d, fn))
        self._chunked = paged and all_blocks_paged(cfg)

        # -- speculative decoding: coordinator-side draft model ----------
        self.spec_tokens = spec_tokens
        self.draft_cfg = draft_cfg
        self.draft = None
        if draft_cfg is not None:
            if draft_params is None:
                raise ValueError("draft_cfg given without draft_params")
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft {draft_cfg.name} vocab {draft_cfg.vocab_size} "
                    f"!= target {cfg.name} vocab {cfg.vocab_size}")
            if spec_tokens < 1:
                raise ValueError(
                    f"spec_tokens must be >= 1, got {spec_tokens}")
            # a full tiny model living at the coordinator; dense positional
            # caches make rejected speculative rows free to overwrite, and
            # sharing engine_cfg keeps slot/row budgets aligned with the
            # target's
            self.draft = StageEngine(draft_cfg, draft_params,
                                     LayerRange(0, draft_cfg.num_layers),
                                     engine_cfg, rng_seed=rng_seed)
        self.spec_proposed = 0       # draft tokens sent to verification
        self.spec_accepted = 0       # draft tokens matching target greedy
        self.spec_rejected = 0       # draft tokens rolled back
        self.spec_rounds = 0         # verify round trips
        self.spec_confirmed = 0      # tokens confirmed by verify rounds
                                     # (accepted prefix + 1 per round)

        self.workers: Dict[str, Any] = {}   # node -> worker process handle
        self.engines: Dict[str, Any] = {}
        for node, rng in sorted(self.placement.assignment.items()):
            self.engines[node] = self._make_engine(node, rng)
        self._sync_kv(capacities=True)

        self.queue: deque = deque()      # _Job awaiting admission
        self.jobs: Dict[int, _Job] = {}  # request_id -> active job
        self._ready: Dict[str, List[dict]] = defaultdict(list)
        self._events: List = []
        self._eseq = 0
        self._jseq = 0
        self._now = 0.0
        self.tokens_produced = 0
        self.completed = 0
        # speculative in-flight passes cancelled by an early stop (eos/len)
        self.cancelled_inflight = 0
        # client-initiated teardowns (``cancel()``): requests ended before
        # finishing, with KV/slots released on every stage node
        self.cancelled_requests = 0
        # per-node decode telemetry for the autoscaler's straggler detector:
        # cumulative wall seconds inside decode passes and tokens batched
        # through them (written on the loop thread; readers snapshot-copy)
        self.node_decode_s: Dict[str, float] = defaultdict(float)
        self.node_decode_tokens: Dict[str, int] = defaultdict(int)
        # request_id -> the pipeline it was (last) served on, for
        # introspection: drivers assert multi-stage serving actually happened
        self.served: Dict[int, Any] = {}
        # virtual-clock latency: first-token confirm time, and mean
        # per-token decode latency recorded at completion
        self._vfirst: Dict[int, float] = {}
        self.decode_latencies: Dict[int, float] = {}

    # -- engine construction ------------------------------------------------
    def _engine_spec(self, node: str, rng: LayerRange) -> Dict[str, Any]:
        """Paged/dense choice + pool sizing for a node's slice — shared by
        local construction and the worker-init payload, so a remote node's
        pool is sized exactly as a local one's would be."""
        n_paged = stage_num_paged_layers(self.cfg, rng)
        if not self.paged or n_paged == 0:
            # hybrid models can hand a node an all-SSM/MLA slice with no
            # paged block at all — that node serves dense even in paged mode
            return {"paged": False, "num_pages": None, "kv_dtype": None}
        rect = full_rectangle_pages(self.cfg, max_batch=self.ec.max_batch,
                                    max_len=self.ec.max_len,
                                    page_size=self.page_size,
                                    paged_layers=n_paged)
        if node in self.pool_pages:
            pages = self.pool_pages[node]
        else:
            # int8 pages cost ~half the bytes, so the same VRAM yields ~2x
            # the pages (still capped at the full rectangle)
            pages = pages_for_vram(self.cfg,
                                   self.cluster.nodes[node].vram_bytes,
                                   page_size=self.page_size,
                                   layers_on_node=rng.num_layers,
                                   max_pages=rect,
                                   kv_dtype=self.kv_dtype)
            # floor: one full-budget request must always fit
            blocks = -(-self.ec.max_len // self.page_size)
            pages = max(pages, 1 + blocks * n_paged)
        return {"paged": True, "num_pages": pages, "kv_dtype": self.kv_dtype}

    def _make_engine(self, node: str, rng: LayerRange):
        if self._engine_factory is not None:
            return self._engine_factory(self, node, rng)
        spec = self._engine_spec(node, rng)
        if not spec["paged"]:
            return StageEngine(self.cfg, self.params, rng, self.ec,
                               rng_seed=self.rng_seed)
        return PagedStageEngine(self.cfg, self.params, rng, self.ec,
                                num_pages=spec["num_pages"],
                                page_size=self.page_size,
                                kv_dtype=spec["kv_dtype"],
                                interpret=self.interpret,
                                rng_seed=self.rng_seed)

    # -- role schedulers (disaggregated prefill/decode) -----------------------
    def _build_role_schedulers(self, plan) -> None:
        """Install the IWRR scheduler(s).  When the placement carries
        replica roles (``meta["roles"]``: node -> prefill|decode|mixed)
        with genuinely distinct prefill and decode groups, each role gets
        its own scheduler over its own sub-placement (max-flow recomputed
        on the role's subgraph, KV estimation over the role's nodes);
        otherwise one scheduler serves both, as before."""
        roles = (plan.placement.meta or {}).get("roles") or {}
        pre = {n for n, r in roles.items() if r in ("prefill", "mixed")}
        dec = {n for n, r in roles.items() if r in ("decode", "mixed")}
        if not (pre and dec) or pre == dec:
            self.scheduler = plan.make_scheduler()
            self.sched_prefill = self.scheduler
            return
        from ..core.placement import Placement
        from ..core.planner import plan as _plan

        def sub(nodes: set):
            p = Placement({n: plan.placement.assignment[n] for n in nodes},
                          plan.placement.num_layers,
                          meta=dict(plan.placement.meta))
            bad = p.validate()
            if bad:
                raise ValueError(
                    f"role group {sorted(nodes)} does not cover the model "
                    f"on its own: {bad}")
            return _plan(plan.cluster, plan.model, placement=p)

        self.scheduler = sub(dec).make_scheduler()
        self.sched_prefill = sub(pre).make_scheduler()

    @property
    def disaggregated(self) -> bool:
        return self.sched_prefill is not self.scheduler

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, fn: Callable[[], None]) -> None:
        self._eseq += 1
        heapq.heappush(self._events, (t, self._eseq, fn))

    def _send(self, src: str, dst: str, payload, nbytes: float,
              deliver: Callable[[Any], None]) -> None:
        self.transport.send(src, dst, payload, nbytes, deliver)

    def _act_bytes(self, n_tokens: int) -> float:
        elt = {"bfloat16": 2, "float32": 4}[self.cfg.param_dtype]
        return float(n_tokens * self.cfg.d_model * elt)

    def _kv_bytes(self, tokens: int, n_layers: int) -> float:
        return float(self.profile.kv_bytes_per_token_layer
                     * tokens * n_layers)

    def _fwd_spec(self, eng, dst: Optional[str]
                  ) -> Optional[Tuple[str, int]]:
        """Forward spec ``(dst node, staging tag)`` when this engine's
        output can be pushed worker-to-worker instead of riding the RPC
        reply: the transport advertises direct links, both endpoints are
        forward-capable workers, and the destination is a node (tokens to
        the coordinator always come back on the reply)."""
        if dst is None or dst == COORDINATOR:
            return None
        if not getattr(self.transport, "direct_links", False):
            return None
        alloc = getattr(self.transport, "alloc_tag", None)
        if alloc is None or not getattr(eng, "forward_capable", False):
            return None
        if not getattr(self.engines.get(dst), "forward_capable", False):
            return None
        return (dst, alloc())

    # -- public API ---------------------------------------------------------
    def clock(self) -> float:
        """Seconds on the runtime's own clock: wall time since construction
        (monotonic) for realtime runs, the virtual event clock otherwise.
        EVERY per-request timestamp (``submitted_s`` / ``first_token_s`` /
        ``finished_s``) is stamped from here — one monotonic base, so TTFT
        and TPOT can never go negative when the system wall clock
        (``time.time``) steps under NTP, and they are defined on
        virtual-clock runs too."""
        if self.realtime:
            return time.monotonic() - self._t0
        return self._now

    def _deliver_realtime(self, d: float, fn: Callable[[], None]) -> None:
        """Delivery sink for realtime-over-in-process runs: a modelled link
        delay becomes a real timer into the thread-safe mailbox."""
        if d > 0:
            threading.Timer(d, self._mailbox.put, args=(fn,)).start()
        else:
            self._mailbox.put(fn)

    def submit(self, req: Request, *,
               on_token: Optional[Callable[[int], None]] = None,
               on_done: Optional[Callable[[Request], None]] = None) -> None:
        """Queue a request.  Thread-safe: the online front door calls this
        from HTTP handler threads while ``serve_forever`` steps — the job
        lands in an ingest queue that only the loop thread drains into the
        admission deque.  Raises ``ValueError`` for requests that could
        never serve (mapped to HTTP 400 by the front door).

        ``on_token`` fires on the loop thread once per token the
        coordinator *confirms*, in strict output order — in-flight
        ``max_inflight`` windows and speculative verify rounds never stream
        unconfirmed tokens.  ``on_done`` fires once at completion."""
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if len(req.prompt) > self.ec.max_len:
            raise ValueError(f"prompt of {len(req.prompt)} tokens exceeds "
                             f"max_len {self.ec.max_len}; refusing to "
                             "truncate")
        if req.temperature > 0 and self.draft is not None:
            raise ValueError(
                f"temperature {req.temperature} > 0 is incompatible with "
                f"speculative decoding (spec_tokens={self.spec_tokens}): "
                "verification accepts draft tokens by greedy argmax, so "
                "sampled acceptance would silently change the output "
                "distribution; serve sampled requests on a runtime "
                "without a draft model")
        req.submitted_s = self.clock()
        if on_token is not None or on_done is not None:
            self._listeners[req.request_id] = (on_token, on_done)
        with self._ingest_lock:
            self._ingest_jobs += 1
        self._ingest.put(_Job(req))
        self._mailbox.put(lambda: None)   # wake an idle serve loop

    def cancel(self, request_id: int) -> None:
        """Cancel a request from any thread (the front door calls this when
        a streaming client disconnects).  Rides the same FIFO ingest queue
        as ``submit``, so a cancel issued after a submit can never be
        processed before its job has landed — the loop thread tears the
        request down in ``_do_cancel``: epoch bump (every in-flight decode
        pass, speculative verify round, and disaggregated KV handoff dies
        on delivery), KV/slots released on every stage node, ``on_done``
        fired once with ``finish_reason="cancelled"``.  Unknown or
        already-finished ids are a no-op."""
        self._ingest.put(("cancel", request_id))
        self._mailbox.put(lambda: None)   # wake an idle serve loop

    def call_soon(self, fn: Callable[[], None]) -> None:
        """Run ``fn`` on the loop thread at the next step — the thread-safe
        door through which the autoscaler applies loop-affine mutations
        (``apply_plan``, ``update_weights``, ``fail_node``) while
        ``serve_forever`` runs."""
        self._ingest.put(fn)
        self._mailbox.put(lambda: None)

    def pending(self) -> int:
        """Requests accepted but not finished (ingest + admission queue +
        live jobs) — the front door's 429 admission signal.  Thread-safe:
        reads container sizes and a lock-guarded counter only."""
        with self._ingest_lock:
            ingest = self._ingest_jobs
        return ingest + len(self.queue) + len(self.jobs)

    def _drain_ingest(self) -> None:
        """Move thread-safe submissions into the admission deque and run
        cross-thread control messages (loop thread only —
        ``fail_node``/``apply_plan`` iterate the deque).  Everything rides
        ONE FIFO queue so ordering across kinds is preserved: a cancel
        enqueued after its submit always drains after the job exists."""
        while True:
            try:
                item = self._ingest.get_nowait()
            except _queue.Empty:
                return
            if isinstance(item, _Job):
                with self._ingest_lock:
                    self._ingest_jobs -= 1
                self.queue.append(item)
            elif isinstance(item, tuple) and item and item[0] == "cancel":
                self._do_cancel(item[1])
            else:
                item()               # call_soon thunk

    def _do_cancel(self, request_id: int) -> None:
        """Loop-thread teardown of a queued or live request.  The epoch
        bump invalidates every delivery still addressed to the job —
        decode tokens, staged activation hops, spec verify results, and
        prefill->decode KV handoffs all check the epoch on arrival — and
        ``_release_all`` frees slots/KV on every node holding any (all
        decode stages, prefill-only replicas, the coordinator draft
        engine), so pools drain even mid-handoff."""
        job = self.jobs.pop(request_id, None)
        if job is None:
            for q in self.queue:
                if q.req.request_id == request_id:
                    job = q
                    break
            if job is None:
                return               # finished or never seen: no-op
            self.queue.remove(job)
        req = job.req
        if req.done:
            return
        self.cancelled_inflight += max(0, job.inflight)
        job.epoch += 1
        job.inbox = {}
        job.kv_pending = set()
        self._release_all(job)
        req.done = True
        req.finish_reason = "cancelled"
        req.finished_s = self.clock()
        self._vfirst.pop(request_id, None)
        self.cancelled_requests += 1
        cb = self._listeners.pop(request_id, None)
        if cb is not None and cb[1] is not None:
            cb[1](req)

    def _idle(self) -> bool:
        return not (self.queue or self.jobs or self._events or self._ready
                    or self._mailbox.qsize() or self._ingest.qsize())

    def _inflight_work(self) -> bool:
        """Work whose progress depends on future deliveries: live jobs,
        scheduled events, or stage-work awaiting a decode pass.  A
        non-empty admission queue alone is NOT in-flight — it drains the
        moment running work frees capacity (and never can if nothing is
        running)."""
        return bool(self.jobs or self._events or self._ready)

    def run_until_done(self, max_iters: int = 100000) -> None:
        for _ in range(max_iters):
            if self._idle():
                return
            if self.step():
                continue
            # realtime (socket) transports complete deliveries on their own
            # threads: no local progress just means the bytes are still in
            # flight — block on the mailbox instead of declaring a stall
            if self.realtime and self._await_delivery(self.stall_timeout_s):
                continue
            raise RuntimeError(
                "runtime stalled: queued requests cannot be admitted "
                "(cluster slots/pools too small?); " + self._state())
        if self._idle():
            return                   # finished exactly on the last step
        raise RuntimeError(
            f"not done after {max_iters} iterations; " + self._state())

    def serve_forever(self) -> None:
        """Online event loop: step while accepting thread-safe ``submit()``
        from other threads.  Unlike ``run_until_done`` the workload is
        OPEN — ``_idle()`` means "waiting for the next request", not
        "done", so idle waits block on the mailbox indefinitely and the
        stall timer is armed only while in-flight work exists (an idle
        server is not stalled).  Returns once ``stop_serving()`` has been
        called and everything in flight has drained."""
        while True:
            if self.step():
                continue
            if self._idle() and self._stop_serving.is_set():
                return
            if self._inflight_work():
                # a delivery must land within the stall budget, or the run
                # is declared wedged with diagnostics
                if not self._await_delivery(self.stall_timeout_s):
                    raise RuntimeError(
                        "runtime stalled with work in flight; "
                        + self._state())
            elif self.queue:
                # admission-blocked with nothing running: capacity can
                # never free up (the pool floor guarantees one max-budget
                # request always fits, so this is a genuine wedge)
                raise RuntimeError(
                    "queued requests cannot be admitted "
                    "(cluster slots/pools too small?); " + self._state())
            else:
                # idle: block until a submission or stop_serving wakes us
                self._await_delivery(None)

    def stop_serving(self) -> None:
        """Ask ``serve_forever`` to exit once in-flight work drains.
        Callable from any thread; submissions already accepted are still
        served (the front door stops accepting new ones first)."""
        self._stop_serving.set()
        self._mailbox.put(lambda: None)   # wake a blocked idle wait

    def _await_delivery(self, timeout_s: Optional[float] = None) -> bool:
        """Block for the next transport delivery or ingest wake-up.
        ``timeout_s=None`` blocks indefinitely — the right mode when
        nothing is in flight; a bounded wait is armed only over in-flight
        work, so a deadlocked run still fails fast with diagnostics
        instead of hanging CI."""
        try:
            fn = self._mailbox.get(timeout=timeout_s)
        except _queue.Empty:
            return False
        fn()
        return True

    def _state(self) -> str:
        """Queue / in-flight diagnostics for stall and iteration-budget
        errors — never return silently with work outstanding.  Transports
        that can stall (bounded socket queues) append their per-link
        report, so a wedged link is named in the error."""
        windows = {j.req.request_id: f"{len(j.req.output)}+{j.inflight}"
                   for j in self.jobs.values()}
        ready = {n: len(v) for n, v in self._ready.items() if v}
        describe = getattr(self.transport, "describe", None)
        extra = f" transport={describe()}" if callable(describe) else ""
        spec = self._spec_note()
        with self._ingest_lock:
            ingest = self._ingest_jobs
        return (f"queued={len(self.queue) + ingest} "
                f"in_flight(confirmed+window)={windows} "
                f"pending_events={len(self._events)} ready={ready} "
                f"cancelled_requests={self.cancelled_requests} "
                f"now={self._now:.6f}" + (f" {spec}" if spec else "") + extra)

    def step(self) -> bool:
        """One runtime iteration: admit, drain deliveries due now, then one
        batched decode per node with resident stage-work.  Returns whether
        anything progressed."""
        if self.realtime:
            self._now = max(self._now, time.monotonic() - self._t0)
        self._drain_ingest()
        progressed = self._admit()
        if self._events:
            self._now = max(self._now, self._events[0][0])
            while self._events and self._events[0][0] <= self._now + 1e-12:
                _, _, fn = heapq.heappop(self._events)
                fn()
                progressed = True
        while True:                  # wall-clock deliveries (socket runs)
            try:
                fn = self._mailbox.get_nowait()
            except _queue.Empty:
                break
            fn()
            progressed = True
        for node in [n for n, v in self._ready.items() if v]:
            work = self._ready.pop(node)
            work = [w for w in work if w["job"].epoch == w["epoch"]]
            if work:
                self._decode_node(node, work)
                progressed = True
        self._sync_kv()
        return progressed

    # -- KV feedback --------------------------------------------------------
    def _sync_kv(self, capacities: bool = False) -> None:
        scheds = [self.scheduler]
        if self.sched_prefill is not self.scheduler:
            scheds.append(self.sched_prefill)
        for sched in scheds:
            kv = sched.kv
            if kv is None:
                continue
            for node, eng in self.engines.items():
                if node not in kv.capacity_tokens:
                    continue             # the other role group's node
                if capacities:
                    kv.capacity_tokens[node] = float(eng.kv_tokens_capacity())
                kv.sync(node, float(eng.kv_tokens_used()))

    # -- admission ----------------------------------------------------------
    def _prefill_tokens(self, job: _Job) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after preemption/failover —
        all generated output but the last token (recompute; the last token
        restarts decode)."""
        prompt = np.asarray(job.req.prompt, np.int32)
        if len(job.req.output) > 1:
            prompt = np.concatenate(
                [prompt, np.asarray(job.req.output[:-1], np.int32)])
        return prompt

    def _compile_route(self, job: _Job) -> None:
        """Compile the job's dataflow.  Disaggregated placements schedule a
        pipeline per role and derive the KV handoffs bridging them (decode
        layer l ships from the prefill stage that computed l, unless the
        same node plays both parts and the KV is already home)."""
        if not self.disaggregated:
            pipe = self.scheduler.schedule()
            job.route = Route(prefill=pipe, decode=pipe)
            job.pipe = pipe
            return
        d = self.scheduler.schedule()
        p = self.sched_prefill.schedule()
        handoffs: Dict[int, List[Tuple[str, List[int]]]] = {}
        for sd in d.stages:
            for si, sp in enumerate(p.stages):
                if sp.node == sd.node:
                    continue            # mixed node: KV stays in its slot
                common = [l for l in range(sd.layers.start, sd.layers.end)
                          if sp.layers.start <= l < sp.layers.end]
                if common:
                    handoffs.setdefault(si, []).append((sd.node, common))
        job.route = Route(prefill=p, decode=d, handoffs=handoffs)
        job.pipe = d

    def _admit(self) -> bool:
        progressed = False
        while self.queue:
            job = self.queue[0]
            if job.route is None:
                try:
                    self._compile_route(job)
                except RuntimeError:
                    break               # no route (mid-replan): wait
            S = len(self._prefill_tokens(job))
            need = min(S + 1, self.ec.max_len)
            nodes: List[str] = []       # prefill-first union, slot per node
            for st in (*job.route.prefill.stages, *job.route.decode.stages):
                if st.node not in nodes:
                    nodes.append(st.node)
            taken: List[Tuple[str, int]] = []
            ok = True
            for node in nodes:
                eng = self.engines.get(node)
                slot = eng.alloc_slot(job.req.request_id) if eng else None
                if slot is None or not eng.ensure(slot, need):
                    if slot is not None:
                        eng.free_slot(slot)
                    ok = False
                    break
                taken.append((node, slot))
            if not ok:
                for node, slot in taken:
                    self.engines[node].release(slot)
                break                   # FIFO: wait for running work to free
            self.queue.popleft()
            job.slots = dict(taken)
            job.pos = S
            job.kv_pending = {(si, dst)
                              for si, hs in job.route.handoffs.items()
                              for dst, _ in hs}
            # open the in-flight window: the first decode pass consumes the
            # last known token at position S and produces output index
            # ``next_j`` (a fresh request's prefill token is index 0, so its
            # first decode pass produces index 1; a resumed request restarts
            # from its last confirmed token)
            job.next_j = len(job.req.output) if job.resumed else 1
            job.next_pos = S
            job.inbox = {}
            job.seen = set()
            job.hop_next = {}
            job.hop_stash = {}
            # speculation: take a draft slot and prefill the draft with the
            # same tokens the target saw; greedy-only — sampled requests
            # (and requests that find the draft full) serve non-speculative
            job.draft_slot = None
            job.draft_pos = 0
            if self.draft is not None and job.req.temperature <= 0:
                dslot = self.draft.alloc_slot(job.req.request_id)
                if dslot is not None:
                    self.draft.prefill_stage(dslot,
                                             self._prefill_tokens(job), 0)
                    job.draft_slot = dslot
                    job.draft_pos = job.pos
            job.seq = self._jseq
            self._jseq += 1
            self.jobs[job.req.request_id] = job
            self.served[job.req.request_id] = job.pipe
            self._dispatch_prefill(job)
            progressed = True
        return progressed

    def _dispatch_prefill(self, job: _Job) -> None:
        tokens = self._prefill_tokens(job)
        first = job.route.prefill.stages[0].node
        if self._chunked:
            chunk = tokens[:max(1, self.ec.prompt_len)]
            self._send(COORDINATOR, first, chunk,
                       len(chunk) * self.profile.token_bytes,
                       self._hop(job, 0, off=0))
        else:
            self._send(COORDINATOR, first, tokens,
                       len(tokens) * self.profile.token_bytes,
                       self._hop(job, 0, off=None))

    # -- prefill hops -------------------------------------------------------
    def _hop(self, job: _Job, si: int, off: Optional[int]
             ) -> Callable[[Any], None]:
        epoch = job.epoch
        return lambda payload: self._prefill_at(job, epoch, si, payload, off)

    def _prefill_at(self, job: _Job, epoch: int, si: int, x,
                    off: Optional[int]) -> None:
        """Delivery guard for prefill payloads: drop duplicates, and execute
        chunks strictly in offset order per stage (a transport is allowed to
        duplicate and reorder; KV writes are not allowed to)."""
        if job.epoch != epoch:
            return                      # preempted/requeued mid-flight
        if off is None:                 # single-shot prefill: one hop/stage
            if ("pf", si) in job.seen:
                return
            job.seen.add(("pf", si))
            self._prefill_exec(job, epoch, si, x, None)
            return
        expect = job.hop_next.get(si, 0)
        if off < expect:
            return                      # duplicate of an executed chunk
        if off > expect:                # overtook a predecessor: wait
            job.hop_stash.setdefault(si, {})[off] = x
            return
        self._prefill_exec(job, epoch, si, x, off)
        while job.epoch == epoch:       # run any chunks unblocked by this one
            nxt = job.hop_next.get(si, 0)
            stash = job.hop_stash.get(si, {})
            if nxt not in stash:
                break
            self._prefill_exec(job, epoch, si, stash.pop(nxt), nxt)

    def _chunk_tokens(self, job: _Job, off: Optional[int]) -> int:
        """Token count of the prefill payload at offset ``off`` — derived
        from the request, not the payload (socket runs deliver opaque
        staged-payload handles)."""
        total = len(self._prefill_tokens(job))
        if off is None:
            return total
        return min(max(1, self.ec.prompt_len), total - off)

    def _prefill_exec(self, job: _Job, epoch: int, si: int, x,
                      off: Optional[int]) -> None:
        stages = job.route.prefill.stages
        st = stages[si]
        eng = self.engines[st.node]
        slot = job.slots[st.node]
        entry = st.layers.start
        n_tok = self._chunk_tokens(job, off)
        last = si == len(stages) - 1
        nxt = None if last else stages[si + 1].node
        # route-driven forwarding: the engine RPC carries the next hop, so
        # a worker pushes its activation frame straight to the next stage's
        # worker and replies with only an ack (the StagedRef the runtime
        # then routes)
        fwd = self._fwd_spec(eng, nxt)
        if self._chunked:
            out = eng.prefill_chunk(slot, x, entry, off,
                                    **({"fwd": fwd} if fwd else {}))
        else:
            out = eng.prefill_stage(slot, x, entry,
                                    **({"fwd": fwd} if fwd else {}))
        if off is not None:
            job.hop_next[si] = off + n_tok
        if not last:
            self._send(st.node, nxt, out, self._act_bytes(n_tok),
                       self._hop(job, si + 1, off))
        if self._chunked and si == 0:
            # stage 0 freed: stream the next chunk in behind this one
            tokens = self._prefill_tokens(job)
            nxt_off = off + n_tok
            if nxt_off < len(tokens):
                chunk = tokens[nxt_off:nxt_off + max(1, self.ec.prompt_len)]
                self._send(COORDINATOR, st.node, chunk,
                           len(chunk) * self.profile.token_bytes,
                           self._hop(job, 0, off=nxt_off))
        stage_done = off is None or off + n_tok >= job.pos
        if stage_done:
            # this stage's KV is complete: ship it to the decode replica(s)
            # that will read these layers (disaggregated placements only)
            for dst, lays in job.route.handoffs.get(si, []):
                self._start_handoff(job, epoch, si, dst, lays)
        if last and stage_done:
            # final chunk left the final stage: out is last-token logits
            if job.resumed:
                tok = job.req.output[-1]      # sampled before eviction
            else:
                tok = eng.sample(out, job.req.temperature)
            self._send(st.node, COORDINATOR, tok, self.profile.token_bytes,
                       lambda t: self._on_first_token(job, epoch, t))
            # at depth >= 2 decode starts here — the first pass leaves for
            # stage 0 while the prefill token travels to the coordinator.
            # Depth 1 always waits for the coordinator (also for resumed
            # requests, whose token needs no confirmation): the documented
            # classic walk, so depth-1 latency is comparable on any trace.
            if self.max_inflight > 1:
                self._maybe_launch(job, st.node, int(tok), job.next_j)

    # -- KV handoff (disaggregated prefill -> decode) ------------------------
    def _start_handoff(self, job: _Job, epoch: int, si: int, dst: str,
                       layers: List[int]) -> None:
        """Ship one prefill stage's filled KV (prompt tokens x ``layers``)
        to a decode replica.  Over direct links the export is pushed
        worker-to-worker (int8 pages + scales travel as-is); otherwise the
        payload rides the reply and the transport stages it — either way
        the decode launch stays gated on ``kv_pending``."""
        st = job.route.prefill.stages[si]
        eng = self.engines.get(st.node)
        if eng is None or st.node not in job.slots:
            return                      # mid-failover: the job will requeue
        fwd = self._fwd_spec(eng, dst)
        payload = eng.export_kv(job.slots[st.node], job.pos, layers,
                                **({"fwd": fwd} if fwd else {}))
        self._send(st.node, dst, payload, self._kv_bytes(job.pos,
                                                         len(layers)),
                   lambda p, jb=job, e=epoch, s=si, d=dst:
                   self._finish_handoff(jb, e, s, d, p))

    def _finish_handoff(self, job: _Job, epoch: int, si: int, dst: str,
                        payload) -> None:
        if job.epoch != epoch:
            return
        key = ("kv", si, dst)
        if key in job.seen:
            return                      # duplicated delivery (chaos link)
        job.seen.add(key)
        eng = self.engines.get(dst)
        if eng is None or dst not in job.slots:
            return
        eng.import_kv(job.slots[dst], job.pos, payload)
        job.kv_pending.discard((si, dst))
        self._maybe_release_prefill(job)
        if not job.kv_pending and job.req.output:
            # the first token may have confirmed while KV was in flight —
            # its launch attempt was gated; relaunch now that decode can run
            self._maybe_launch(job, COORDINATOR, int(job.req.output[-1]),
                               len(job.req.output))
            self._drain_inbox(job)

    def _maybe_release_prefill(self, job: _Job) -> None:
        """Free prefill-only nodes' slots (and KV) once every handoff out
        of them has landed — long prompts stop holding decode-side pools,
        which is the point of disaggregating."""
        if not job.route.disaggregated:
            return
        decode_nodes = {st.node for st in job.route.decode.stages}
        pending_src = {job.route.prefill.stages[s].node
                       for s, _ in job.kv_pending}
        for st in job.route.prefill.stages:
            if st.node in decode_nodes or st.node in pending_src:
                continue
            slot = job.slots.pop(st.node, None)
            if slot is not None:
                eng = self.engines.get(st.node)
                if eng is not None:
                    eng.release(slot)

    # -- token arrivals (coordinator) ----------------------------------------
    def _confirm(self, job: _Job, tok: int) -> None:
        """Confirm ONE token at the coordinator: append it to the visible
        output, stamp the first-token time (on the runtime clock, so it is
        defined for virtual-clock runs too), and stream it to any listener.
        Every confirmed token — classic walk, in-flight window drain, or
        speculative verify acceptance — flows through here, so SSE streams
        see tokens strictly in confirmation order."""
        req = job.req
        req.output.append(int(tok))
        self.tokens_produced += 1
        if req.first_token_s is None:
            req.first_token_s = self.clock()
        self._vfirst.setdefault(req.request_id, self._now)
        cb = self._listeners.get(req.request_id)
        if cb is not None and cb[0] is not None:
            cb[0](int(tok))

    def _stop_reason(self, job: _Job) -> Optional[str]:
        req = job.req
        if int(req.output[-1]) == self.ec.eos_token:
            return "stop"
        if len(req.output) >= req.max_new_tokens:
            return "length"
        if job.pos >= self.ec.max_len:
            return "length"
        return None

    def _on_first_token(self, job: _Job, epoch: int, tok: int) -> None:
        """Prefill's token reached the coordinator (resumed requests re-send
        their last confirmed token instead of sampling a new one)."""
        if job.epoch != epoch:
            return
        if ("first",) in job.seen:
            return                      # duplicated delivery (chaos link)
        job.seen.add(("first",))
        req = job.req
        if not job.resumed:
            self._confirm(job, int(tok))
            reason = self._stop_reason(job)
            if reason is not None:
                self._complete(job, reason)
                return
        # depth 1 (or a closed window at prefill time): the first decode
        # pass launches from here, exactly the classic walk.  The expected
        # index is the one consuming our newest confirmed token — if the
        # final stage already launched it, this is a no-op.
        self._maybe_launch(job, COORDINATOR, int(req.output[-1]),
                           len(req.output))
        # a reordering transport may have delivered decode tokens first
        self._drain_inbox(job)

    def _on_decode_token(self, job: _Job, epoch: int, j: int, tok: int
                         ) -> None:
        """A sampled token arrived.  Confirm strictly in output order —
        arrivals ahead of the expected index wait in the job's inbox."""
        if job.epoch != epoch:
            return
        if j < len(job.req.output):
            return                      # duplicate of a confirmed token
        job.inbox[j] = int(tok)
        self._drain_inbox(job)

    def _drain_inbox(self, job: _Job) -> None:
        req = job.req
        while len(req.output) in job.inbox:
            t = job.inbox.pop(len(req.output))
            self._confirm(job, t)
            job.pos += 1
            reason = self._stop_reason(job)
            if reason is not None:
                self._complete(job, reason)
                return
            self._maybe_launch(job, COORDINATOR, t, len(req.output))

    # -- speculative verify results (coordinator) -----------------------------
    def _on_spec_result(self, job: _Job, epoch: int, j: int, greedy) -> None:
        """A verify pass's greedy vector reached the coordinator: accept
        the longest draft prefix, confirm those tokens (plus the bonus
        token) strictly in order, and on the first mismatch bump the epoch
        and roll every decode stage node back to the accepted prefix."""
        if job.epoch != epoch:
            return
        key = ("spec", j, epoch)
        if key in job.seen:
            return                      # duplicated delivery (chaos link)
        job.seen.add(key)
        req = job.req
        drafts = job.spec_drafts
        greedy = [int(t) for t in np.asarray(greedy).reshape(-1)]
        gamma = len(greedy) - 1
        a = 0
        while a < gamma and drafts[a] == greedy[a]:
            a += 1
        self.spec_accepted += a
        self.spec_rejected += gamma - a
        base = job.spec_base
        # draft rows base+1..base+min(a, γ-1) hold proposals the target
        # just confirmed — the draft need not re-consume them next round
        job.draft_pos = max(job.draft_pos, base + 1 + min(a, gamma - 1))
        for t in greedy[:a + 1]:
            self._confirm(job, int(t))
            self.spec_confirmed += 1
            job.pos += 1
            reason = self._stop_reason(job)
            if reason is not None:
                # early stop inside the accepted prefix: completion releases
                # every slot wholesale — no rollback needed
                self._complete(job, reason)
                self._spec_annotate()
                return
        if a < gamma:
            # rejection: cancel the optimistic window (the PR 4
            # cancelled_inflight path) and bump the epoch so straggling
            # duplicates of the dead pass cannot decode after the rollback
            keep = base + a + 1
            self.cancelled_inflight += max(0, job.inflight)
            job.epoch += 1
            job.next_j = len(req.output)
            job.next_pos = keep
            self._rollback_job(job, keep)
        self._spec_annotate()
        self._maybe_launch(job, COORDINATOR, int(req.output[-1]),
                           len(req.output))

    def _rollback_job(self, job: _Job, keep: int) -> None:
        """Synchronously truncate the job's KV to ``keep`` rows on every
        decode stage node (an RPC for remote engines), so the relaunched
        pass cannot race the rollback.  The draft engine needs no rollback:
        its dense caches are positional and ``draft_pos`` already points at
        the last confirmed row."""
        done = set()
        for st in job.pipe.stages:
            if st.node in done:
                continue
            done.add(st.node)
            eng = self.engines.get(st.node)
            slot = job.slots.get(st.node)
            if eng is None or slot is None:
                continue
            eng.rollback(slot, keep)

    def _spec_note(self) -> str:
        if self.draft is None:
            return ""
        return (f"spec[proposed={self.spec_proposed} "
                f"accepted={self.spec_accepted} "
                f"rejected={self.spec_rejected} "
                f"rate={self.spec_acceptance_rate:.2f} "
                f"tokens/rt={self.spec_tokens_per_round_trip:.2f}]")

    def _spec_annotate(self) -> None:
        ann = getattr(self.transport, "annotations", None)
        if ann is not None:
            ann["spec"] = self._spec_note()

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of draft proposals the target's greedy pass accepted."""
        return self.spec_accepted / max(1, self.spec_proposed)

    @property
    def spec_tokens_per_round_trip(self) -> float:
        """Tokens confirmed per verify round trip (1 + accepted prefix;
        the in-flight-window-only baseline is 1 by construction)."""
        return self.spec_confirmed / max(1, self.spec_rounds)

    # -- decode pass launch (window) -----------------------------------------
    def _spec_gamma(self, job: _Job) -> int:
        """Draft length for the next verify round, clamped so every
        position could still be confirmed: the round produces output
        indices ``next_j .. next_j+γ`` (full acceptance exactly reaches
        ``max_new_tokens``) and writes cache rows ``next_pos .. next_pos+γ``
        (staying under ``max_len``)."""
        return max(0, min(self.spec_tokens,
                          job.req.max_new_tokens - job.next_j - 1,
                          self.ec.max_len - 1 - job.next_pos))

    def _draft_propose(self, job: _Job, gamma: int) -> List[int]:
        """Run the coordinator-side draft autoregressively: catch up on
        confirmed tokens it has not yet consumed (one multi-token decode
        over rows ``draft_pos..next_pos``), then propose ``gamma`` greedy
        tokens.  Rejected speculative rows from earlier rounds are simply
        overwritten — dense caches are positional and mask by pos."""
        eng, slot = self.draft, job.draft_slot
        req = job.req
        P = len(req.prompt)
        p = job.next_pos

        def tok_at(r: int) -> int:
            # row r >= P holds output[r - P] (prefill fed prompt+output
            # contiguously, so this covers resumed requests too)
            return int(req.prompt[r]) if r < P else int(req.output[r - P])

        catch = [tok_at(r) for r in range(job.draft_pos, p + 1)]
        out = eng.decode_stage([DecodeItem(slot=slot, pos=job.draft_pos,
                                           entry=0, tokens=catch)])[0]
        logits = np.asarray(out.logits)
        cur = int(np.argmax(logits[-1] if logits.ndim == 2 else logits))
        drafts = [cur]
        for s in range(1, gamma):
            out = eng.decode_stage([DecodeItem(slot=slot, pos=p + s,
                                               entry=0, token=cur)])[0]
            cur = int(np.argmax(out.logits))
            drafts.append(cur)
        job.draft_pos = p + 1        # rows 0..p are now confirmed-consumed
        return drafts

    def _maybe_launch(self, job: _Job, src: str, tok: int, expect_j: int
                      ) -> None:
        """Launch the decode pass producing output index ``expect_j`` if no
        one else has (the final stage races the coordinator for it), the
        hard budgets allow it to ever be confirmed, and the in-flight window
        has room.  Sampled-token speculation (eos still unseen by the
        coordinator) launches anyway — completion cancels it by epoch.

        Jobs holding a draft slot launch *verify* passes instead: γ draft
        proposals ride with the confirmed token as one multi-token pass.
        Only the coordinator can launch them (the draft lives there), and
        exactly one verify pass is in flight per request — the optimistic
        window ``next_j = j+γ+1`` closes the window until the round
        confirms or rolls back."""
        req = job.req
        spec = job.draft_slot is not None
        if spec and src != COORDINATOR:
            return                   # final stage cannot draft
        if req.done or job.next_j != expect_j:
            return
        if job.kv_pending:
            return                   # decode KV still in flight from prefill
        if job.next_j >= req.max_new_tokens or job.next_pos >= self.ec.max_len:
            return                   # pass could never be confirmed
        if spec and job.inflight != 0:
            return                   # one verify round in flight at a time
        if job.inflight >= self.max_inflight and not spec:
            return                   # window full: coordinator relaunches
        gamma = self._spec_gamma(job) if spec else 0
        pos, j, epoch = job.next_pos, job.next_j, job.epoch
        if not self._reserve_inflight(job, pos + gamma + 1):
            return                   # job itself was preempted reserving
        first = job.pipe.stages[0].node
        if gamma >= 1:
            drafts = self._draft_propose(job, gamma)
            job.spec_drafts = drafts
            job.spec_base = pos
            job.next_j = j + gamma + 1     # optimistic: rolled back on
            job.next_pos = pos + gamma + 1  # rejection (epoch bump)
            self.spec_rounds += 1
            self.spec_proposed += gamma
            toks = np.asarray([int(tok)] + drafts, np.int32)
            self._send(src, first, toks,
                       (gamma + 1) * self.profile.token_bytes,
                       lambda t, e=epoch, p=pos, jj=j, n=gamma + 1:
                       self._enqueue_decode(job, e, 0, 0, None, p, jj,
                                            toks=t, spec=True, nt=n))
            return
        job.next_j = j + 1
        job.next_pos = pos + 1
        self._send(src, first, int(tok), self.profile.token_bytes,
                   lambda t, e=epoch, p=pos, jj=j:
                   self._enqueue_decode(job, e, 0, int(t), None, p, jj))

    def _enqueue_decode(self, job: _Job, epoch: int, si: int, tok: int,
                        h, pos: int, j: int, toks=None, spec: bool = False,
                        nt: int = 1) -> None:
        """Delivery guard for decode stage-work: a duplicated delivery of
        the same (stage, output-index) pass is dropped — running it twice
        would double-decode the pass (and two copies in one batch would
        trip the engine's duplicate-slot invariant).  The epoch is part of
        the key: after a rejected verify rolls a job back, the same output
        index relaunches under a bumped epoch and must not be mistaken for
        a duplicate of the cancelled pass."""
        if job.epoch != epoch:
            return
        key = ("dw", si, j, epoch)
        if key in job.seen:
            return
        job.seen.add(key)
        node = job.pipe.stages[si].node
        self._ready[node].append(dict(job=job, epoch=epoch, si=si, tok=tok,
                                      h=h, pos=pos, j=j, toks=toks,
                                      spec=spec, nt=nt))

    def _grow_or_preempt(self, eng, node: str, job: _Job, tokens: int
                         ) -> bool:
        """Grow ``job``'s KV on ``node`` to hold ``tokens``, preempting the
        newest resident request (pipeline-wide) while the pool is dry.
        Returns False when the victim chain reached ``job`` itself."""
        epoch = job.epoch
        while not eng.ensure(job.slots[node], tokens):
            live = [j for j in self.jobs.values() if node in j.slots]
            victim = max(live, key=lambda j: j.seq)
            self._preempt(victim)
            if job.epoch != epoch:
                return False
        return True

    def _reserve_inflight(self, job: _Job, tokens: int) -> bool:
        """Reserve KV for an in-flight token on every stage node *at launch*
        so it can never land mid-pipeline on an exhausted pool; returns
        False when the job itself got preempted making room."""
        for st in job.pipe.stages:
            eng = self.engines.get(st.node)
            if eng is None or st.node not in job.slots:
                return False         # mid-failover: the job will requeue
            if not self._grow_or_preempt(eng, st.node, job, tokens):
                return False
        return True

    # -- decode (per-node continuous batching) -------------------------------
    def _decode_node(self, node: str, work: List[dict]) -> None:
        """All stage-work resident at ``node`` this iteration.  At most one
        decode pass per request is ever inside the stages (pass t+1 is born
        at the final stage only after pass t exits it), so ``work`` holds at
        most one item per request — ``stage_engine._assemble`` rejects
        duplicate cache slots if that invariant is ever broken."""
        eng = self.engines.get(node)
        if eng is None:
            return
        # grow pools oldest-first, as a backstop: launch-time reservation
        # makes this a cheap no-op unless another request raced the pool dry
        for w in sorted(work, key=lambda w: w["job"].seq):
            job = w["job"]
            if job.epoch != w["epoch"]:
                continue
            self._grow_or_preempt(eng, node, job, w["pos"] + w.get("nt", 1))
        while work:
            batch = [w for w in work[:self.ec.max_batch]
                     if w["job"].epoch == w["epoch"]]
            work = work[self.ec.max_batch:]
            if not batch:
                continue
            items = [DecodeItem(slot=w["job"].slots[node], pos=w["pos"],
                                entry=w["job"].pipe.stages[w["si"]]
                                .layers.start,
                                token=w["tok"], h=w["h"],
                                tokens=w.get("toks")) for w in batch]
            fwds = None
            if getattr(eng, "forward_capable", False) and \
                    getattr(self.transport, "direct_links", False):
                fwds = []
                for w in batch:
                    pipe = w["job"].pipe
                    nxt = (None if w["si"] == len(pipe.stages) - 1
                           else pipe.stages[w["si"] + 1].node)
                    fwds.append(self._fwd_spec(eng, nxt))
            t_pass = time.monotonic()
            if fwds and any(f is not None for f in fwds):
                outs = eng.decode_stage(items, fwds=fwds)
            else:
                outs = eng.decode_stage(items)
            # straggler telemetry: wall seconds per batched token, per node
            self.node_decode_s[node] += time.monotonic() - t_pass
            self.node_decode_tokens[node] += sum(
                w.get("nt", 1) for w in batch)
            for w, out in zip(batch, outs):
                job, si, epoch, j = w["job"], w["si"], w["epoch"], w["j"]
                if si == len(job.pipe.stages) - 1:
                    if w.get("spec"):
                        # verify pass: no sampling, no node-side launch —
                        # the greedy argmax vector (one per verified
                        # position; identical to what sample() computes at
                        # temperature <= 0) returns to the coordinator,
                        # which owns acceptance and rollback
                        greedy = np.asarray(
                            np.argmax(np.asarray(out.logits), axis=-1),
                            np.int32).reshape(-1)
                        self._send(node, COORDINATOR, (j, greedy),
                                   len(greedy) * self.profile.token_bytes,
                                   lambda p, jb=job, e=epoch:
                                   self._on_spec_result(jb, e, p[0], p[1]))
                        continue
                    tok = eng.sample(out.logits, job.req.temperature)
                    self._send(node, COORDINATOR, (j, tok),
                               self.profile.token_bytes,
                               lambda p, jb=job, e=epoch:
                               self._on_decode_token(jb, e, p[0], p[1]))
                    # speculative: token j leaves for the coordinator while
                    # the pass for j+1 leaves for stage 0
                    self._maybe_launch(job, node, tok, j + 1)
                else:
                    nxt = job.pipe.stages[si + 1].node
                    n = w.get("nt", 1)
                    self._send(node, nxt, out.h, self._act_bytes(n),
                               lambda h, jb=job, e=epoch, s=si + 1,
                               p=w["pos"], jj=j, sp=w.get("spec", False),
                               nn=n:
                               self._enqueue_decode(jb, e, s, 0, h, p, jj,
                                                    spec=sp, nt=nn))

    # -- completion / preemption ---------------------------------------------
    def _release_all(self, job: _Job) -> None:
        for node, slot in job.slots.items():
            eng = self.engines.get(node)
            if eng is not None:
                eng.release(slot)
        job.slots = {}
        if job.draft_slot is not None and self.draft is not None:
            self.draft.release(job.draft_slot)
        job.draft_slot = None
        job.draft_pos = 0

    def _complete(self, job: _Job, reason: str) -> None:
        req = job.req
        req.done = True
        req.finish_reason = reason
        req.finished_s = self.clock()
        # cancel speculative in-flight passes (a stop confirmed while token
        # t+1 is mid-pipeline): the epoch bump kills their deliveries; KV
        # they reserved is released with the slots below
        self.cancelled_inflight += max(0, job.inflight)
        job.epoch += 1
        job.inbox = {}
        t0 = self._vfirst.pop(req.request_id, None)
        if t0 is not None and len(req.output) > 1:
            self.decode_latencies[req.request_id] = \
                (self._now - t0) / (len(req.output) - 1)
        self._release_all(job)
        self.jobs.pop(req.request_id, None)
        self.completed += 1
        cb = self._listeners.pop(req.request_id, None)
        if cb is not None and cb[1] is not None:
            cb[1](req)

    def _preempt(self, job: _Job) -> None:
        """Pool exhausted: evict pipeline-wide, keep generated tokens, requeue
        at the front (recompute-on-readmit, same pipeline)."""
        self._requeue(job, clear_pipe=False)

    # -- failover ------------------------------------------------------------
    def fail_node(self, name: str) -> None:
        """Kill a node's engine; every request whose pipeline crossed it is
        requeued (its KV on survivors released) pending a replanned pipeline."""
        eng = self.engines.pop(name, None)
        close = getattr(eng, "close", None)
        if callable(close):
            close()                  # remote: drop the (possibly dead) channel
        proc = self.workers.pop(name, None)
        if proc is not None and proc.poll() is None:
            proc.terminate()
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=10)    # reap: no zombie per failover
        for job in list(self.jobs.values()):
            if name in job.route.nodes:
                self._requeue(job, clear_pipe=True)
        for job in self.queue:
            if job.route is not None and name in job.route.nodes:
                job.pipe = None
                job.route = None

    def _requeue(self, job: _Job, clear_pipe: bool) -> None:
        job.epoch += 1               # cancels every in-flight pass
        job.inbox = {}
        job.kv_pending = set()       # readmission restarts any KV handoff
        self._release_all(job)
        if clear_pipe:
            job.pipe = None
            job.route = None
        self.jobs.pop(job.req.request_id, None)
        job.req.preemptions += 1
        self.queue.appendleft(job)

    def apply_plan(self, plan) -> None:
        """Adopt a replanned placement: rebuild engines whose slice changed
        (requeueing their resident requests), swap IWRR weights in place when
        the placement survived, else install a fresh scheduler, and re-sync
        true pool occupancy into the KV estimator."""
        new_assign = plan.placement.assignment
        for node in [n for n in self.engines if n not in new_assign]:
            self.fail_node(node)
        old_assign = self.placement.assignment
        old_roles = (self.placement.meta or {}).get("roles")
        # install the new topology BEFORE building engines: pool sizing
        # reads node VRAM from self.cluster, and an autoscale scale-up plan
        # places layers on nodes that exist only in plan.cluster
        self.cluster = plan.cluster
        self.profile = plan.model
        changed = set()
        for node, rng in sorted(new_assign.items()):
            if node in self.engines and old_assign.get(node) == rng:
                continue
            changed.add(node)
            for job in list(self.jobs.values()):
                if node in job.slots:
                    self._requeue(job, clear_pipe=True)
            self.engines[node] = self._make_engine(node, rng)
        # queued jobs (e.g. preempted ones holding their old pipeline) whose
        # cached pipeline crosses a rebuilt node would execute stale layer
        # ranges — force them to reschedule
        for job in self.queue:
            if job.route is not None and \
                    changed.intersection(job.route.nodes):
                job.pipe = None
                job.route = None
        same = (old_assign == new_assign
                and old_roles == (plan.placement.meta or {}).get("roles"))
        self.placement = plan.placement
        if same and not self.disaggregated and \
                self.scheduler.placement.assignment == new_assign:
            self.scheduler.update_weights(plan.flows)
        else:
            kv_old = self.scheduler.kv
            kv_pre = self.sched_prefill.kv
            self._build_role_schedulers(plan)
            if self.scheduler.kv is not None and kv_old is not None:
                self.scheduler.kv.high_water = kv_old.high_water
            if self.sched_prefill is not self.scheduler and \
                    self.sched_prefill.kv is not None and kv_pre is not None:
                self.sched_prefill.kv.high_water = kv_pre.high_water
        self._sync_kv(capacities=True)

    # -- introspection --------------------------------------------------------
    def node_occupancy(self) -> Dict[str, float]:
        """Per-node KV occupancy fraction (used tokens / capacity tokens) —
        the autoscaler's saturation signal.  Nodes whose engine exposes no
        KV accounting report 0.0."""
        out = {}
        for n, e in self.engines.items():
            used = getattr(e, "kv_tokens_used", None)
            cap = getattr(e, "kv_tokens_capacity", None)
            if callable(used) and callable(cap):
                c = cap()
                out[n] = (used() / c) if c else 0.0
            else:
                out[n] = 0.0
        return out

    def pool_pages_used(self) -> Dict[str, int]:
        out = {}
        for n, e in self.engines.items():
            used = e.pool_used()
            if used is not None:
                out[n] = used
        return out

    def mean_decode_latency(self) -> float:
        """Mean per-token decode latency on the virtual clock, over
        completed requests that decoded at least one token past prefill —
        the number the in-flight window is meant to shrink."""
        lats = list(self.decode_latencies.values())
        return sum(lats) / len(lats) if lats else 0.0

    # -- multi-process workers ------------------------------------------------
    @classmethod
    def spawn_workers(cls, cfg: ModelConfig, params, plan,
                      engine_cfg: EngineConfig, *,
                      connect: Optional[str] = None,
                      queue_depth: int = 8,
                      worker_timeout_s: float = 300.0,
                      direct_links: bool = False,
                      **kw) -> "ClusterRuntime":
        """Build a runtime whose stage engines live in separate OS
        processes behind a ``SocketTransport``.

        By default one ``repro.launch.worker`` subprocess is launched per
        placed node and dialled back over loopback TCP.  With ``connect``
        ("host:port") the coordinator instead listens there and waits for
        externally started workers (``python -m repro.launch.worker
        --connect host:port`` on each machine), accepting one per node in
        sorted-node order.  Everything a node needs — config, params, its
        layer slice, pool sizing — ships over the wire at init, so workers
        start from nothing but the address.

        Failover works by killing a worker (``kill_worker``/``fail_node``);
        ``apply_plan`` re-inits surviving workers whose slice moved over
        their existing channels and respawns processes for dead nodes that
        re-enter the placement.  Call ``shutdown()`` when done.
        """
        nodes = sorted(plan.placement.assignment)
        channels: Dict[str, WorkerChannel] = {}
        procs: Dict[str, Any] = {}

        def _spawn(node: str) -> WorkerChannel:
            lsock = _socket.socket()
            lsock.bind(("127.0.0.1", 0))
            lsock.listen(1)
            host, port = lsock.getsockname()
            env = dict(os.environ)
            src_root = os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            env["PYTHONPATH"] = src_root + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH")
                else "")
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.launch.worker",
                 "--connect", f"{host}:{port}",
                 "--timeout-s", str(worker_timeout_s)],
                env=env)
            lsock.settimeout(worker_timeout_s)
            try:
                conn, _ = lsock.accept()
            except _socket.timeout:
                proc.kill()
                raise RuntimeError(
                    f"worker for {node} did not dial back within "
                    f"{worker_timeout_s}s") from None
            finally:
                lsock.close()
            procs[node] = proc
            return WorkerChannel(conn, node=node, timeout_s=worker_timeout_s)

        if connect is not None:
            host, _, port = connect.rpartition(":")
            lsock = _socket.socket()
            lsock.setsockopt(_socket.SOL_SOCKET, _socket.SO_REUSEADDR, 1)
            lsock.bind((host or "0.0.0.0", int(port)))
            lsock.listen(len(nodes))
            lsock.settimeout(worker_timeout_s)
            print(f"waiting for {len(nodes)} workers on {connect} ...")
            try:
                for node in nodes:
                    conn, addr = lsock.accept()
                    channels[node] = WorkerChannel(conn, node=node,
                                                   timeout_s=worker_timeout_s)
                    print(f"  {node} <- worker at {addr[0]}:{addr[1]}")
            finally:
                lsock.close()
        else:
            for node in nodes:
                channels[node] = _spawn(node)

        transport = SocketTransport(channels, queue_depth=queue_depth,
                                    direct_links=direct_links)
        cfg_wire = dataclasses.asdict(cfg)
        ec_wire = dataclasses.asdict(engine_cfg)

        def _wire_peers() -> None:
            """(Re)build the worker-to-worker mesh: ask every live worker
            for its peer listener port, then broadcast the full address
            book.  Runs after every init/respawn so a replaced worker's
            new port propagates; workers drop channels whose address
            changed and re-dial lazily."""
            addrs: Dict[str, Tuple[str, int]] = {}
            for node, ch in sorted(channels.items()):
                if not ch.alive:
                    continue
                try:
                    port = ch.call("peer_addr")
                    host = ch.sock.getpeername()[0]
                except (WorkerDied, OSError):
                    continue
                addrs[node] = (host, int(port))
            for node, ch in sorted(channels.items()):
                if not ch.alive:
                    continue
                try:
                    ch.call("set_peers", addrs)
                except (WorkerDied, OSError):
                    pass

        def factory(rt: "ClusterRuntime", node: str, rng: LayerRange):
            import jax
            # converted per init/respawn and then dropped — holding a
            # permanent numpy copy would double the coordinator's weight
            # footprint for the runtime's whole life
            params_np = jax.tree.map(np.asarray, rt.params)
            ch = channels.get(node)
            if ch is None or not ch.alive:
                if connect is not None:
                    raise WorkerDied(
                        f"no live worker for {node} and external workers "
                        "cannot be respawned by the coordinator")
                ch = _spawn(node)
                channels[node] = ch
                rt.workers[node] = procs[node]
                transport.channels[node] = ch
                transport.dead.discard(node)
            spec = rt._engine_spec(node, rng)
            ch.call("init", {
                "node": node, "cfg": cfg_wire, "ec": ec_wire,
                "layers": (rng.start, rng.end), "params": params_np,
                "paged": spec["paged"], "num_pages": spec["num_pages"],
                "page_size": rt.page_size, "kv_dtype": spec["kv_dtype"],
                "interpret": rt.interpret, "rng_seed": rt.rng_seed})
            if direct_links:
                _wire_peers()
            return RemoteStageEngine(ch, node, rng_seed=rt.rng_seed)

        rt = cls(cfg, params, plan, engine_cfg, transport=transport,
                 engine_factory=factory, **kw)
        rt.workers.update(procs)
        return rt

    def kill_worker(self, name: str) -> None:
        """Hard-kill a node's worker process (fault injection: SIGKILL, no
        cleanup) — the caller then drives ``fail_node`` + replan +
        ``apply_plan`` exactly as for any node loss."""
        proc = self.workers.get(name)
        if proc is None:
            raise ValueError(f"{name} has no worker process")
        proc.kill()
        proc.wait(timeout=30)

    def shutdown(self) -> None:
        """Tear down remote workers and transport threads (no-op for pure
        in-process runtimes)."""
        for eng in self.engines.values():
            close = getattr(eng, "close", None)
            if callable(close):
                close()
        close = getattr(self.transport, "close", None)
        if callable(close):
            close()
        for proc in self.workers.values():
            if proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    proc.kill()
        self.workers.clear()
