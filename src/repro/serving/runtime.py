"""ClusterRuntime: execute IWRR pipelines across per-node stage engines.

This is the execution plane the paper's runtime scheduling (§4) assumes: the
MILP places layer slices on nodes, max-flow IWRR walks per-request pipelines,
and *this* module actually runs them — each node owns a stage engine over its
assigned ``LayerRange``, activations hop between nodes through a pluggable
``Transport``, and every node continuously batches whatever stage-work (from
any request, entering at any layer) is resident each iteration.

Event loop: a virtual-clock heap of deliveries.  Prefill hops execute inline
as they arrive (per-request; chunked across stages for all-paged stacks);
decode inputs accumulate in per-node inboxes and run as ONE batched
``decode_stage`` per node per iteration — per-node continuous batching.  The
final stage samples the token and ships it to the coordinator, which starts
the next decode pass (one outstanding token per request, as in the paper).

Memory: admission takes a slot (and, paged, the prompt's pages) on *every*
stage node up front; completion and preemption release KV on every node of
the pipeline.  When a pool runs dry mid-decode the newest resident request is
preempted pipeline-wide (recompute-on-readmit keeps its generated tokens).

Scheduler feedback: after every iteration the runtime writes each node's true
pool occupancy into the scheduler's ``KVEstimator`` (``sync``), and installs
real pool capacities at startup — IWRR masking reflects actual paged usage
rather than arrival-time reservations drifting from reality.

Failover: ``fail_node`` drops a node's engine and requeues every in-flight
request that crossed it; after the planner replans, ``apply_plan`` rebuilds
engines whose slices changed, swaps IWRR weights (``update_weights`` when the
placement survived, a fresh scheduler otherwise), and the requeued requests
re-prefill (prompt + generated tokens) on fresh pipelines.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..configs.base import ModelConfig
from ..core.cluster import COORDINATOR
from ..core.placement import LayerRange
from ..models.paged import all_blocks_paged
from ..models.stage import stage_num_paged_layers
from .engine import EngineConfig, Request
from .kv_pool import full_rectangle_pages, pages_for_vram
from .stage_engine import (DecodeItem, PagedStageEngine, StageEngine,
                           make_stage_engine)


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------

class Transport:
    """Moves stage payloads (activations / token ids) between nodes.

    ``send`` must eventually call ``deliver(payload)``; implementations may
    move real bytes (RPC) or just model the delay.  The runtime binds
    ``schedule(delay_s, fn)`` at construction so in-process transports can
    put deliveries on the runtime's virtual clock.
    """

    def bind(self, schedule: Callable[[float, Callable[[], None]], None]
             ) -> None:
        self._schedule = schedule

    def send(self, src: str, dst: str, payload: Any, nbytes: float,
             deliver: Callable[[Any], None]) -> None:
        raise NotImplementedError


class InProcessTransport(Transport):
    """Same-process transport: payloads are handed over by reference after an
    optional modelled link delay (latency + nbytes/bandwidth).  This is the
    seam a real RPC transport plugs into later."""

    def __init__(self, default_delay_s: float = 0.0,
                 link_delay_s: Optional[Mapping[Tuple[str, str], float]] = None,
                 bandwidth_bytes_per_s: float = 0.0):
        self.default_delay_s = default_delay_s
        self.link_delay_s = dict(link_delay_s or {})
        self.bandwidth = bandwidth_bytes_per_s
        self.transfers: Dict[Tuple[str, str], int] = defaultdict(int)

    def delay(self, src: str, dst: str, nbytes: float) -> float:
        d = self.link_delay_s.get((src, dst), self.default_delay_s)
        if self.bandwidth > 0:
            d += nbytes / self.bandwidth
        return d

    def send(self, src: str, dst: str, payload: Any, nbytes: float,
             deliver: Callable[[Any], None]) -> None:
        self.transfers[(src, dst)] += 1
        self._schedule(self.delay(src, dst, nbytes),
                       lambda: deliver(payload))


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Job:
    req: Request
    pipe: Any = None                 # RequestPipeline (kept across preemption)
    slots: Dict[str, int] = dataclasses.field(default_factory=dict)
    pos: int = 0                     # tokens resident in caches
    epoch: int = 0                   # bumped on preempt/requeue: stale msgs die
    seq: int = -1                    # admission order (preemption victims)

    @property
    def resumed(self) -> bool:
        return bool(self.req.output)


class ClusterRuntime:
    """Orchestrates one stage engine per placed node (see module docstring).

    ``plan`` is a ``repro.core.planner.Plan``; engines are built from its
    placement, with paged pools sized from each node's own VRAM (capped at
    the full rectangle, floored at one max_len request).
    """

    def __init__(self, cfg: ModelConfig, params, plan, engine_cfg: EngineConfig,
                 *, paged: bool = True, page_size: int = 16,
                 pool_pages: Optional[Mapping[str, int]] = None,
                 transport: Optional[Transport] = None,
                 interpret: Optional[bool] = None, rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.paged = paged
        self.page_size = page_size
        self.pool_pages = dict(pool_pages or {})
        self.interpret = interpret
        self.rng_seed = rng_seed
        self.cluster = plan.cluster
        self.placement = plan.placement
        self.profile = plan.model
        if plan.model.num_layers != cfg.num_layers:
            raise ValueError(f"plan covers {plan.model.num_layers} layers; "
                             f"{cfg.name} has {cfg.num_layers}")
        self.scheduler = plan.make_scheduler()
        self.transport = transport or InProcessTransport()
        self.transport.bind(lambda d, fn: self._push(self._now + d, fn))
        self._chunked = paged and all_blocks_paged(cfg)

        self.engines: Dict[str, Any] = {}
        for node, rng in sorted(self.placement.assignment.items()):
            self.engines[node] = self._make_engine(node, rng)
        self._sync_kv(capacities=True)

        self.queue: deque = deque()      # _Job awaiting admission
        self.jobs: Dict[int, _Job] = {}  # request_id -> active job
        self._ready: Dict[str, List[dict]] = defaultdict(list)
        self._events: List = []
        self._eseq = 0
        self._jseq = 0
        self._now = 0.0
        self.tokens_produced = 0
        self.completed = 0
        # request_id -> the pipeline it was (last) served on, for
        # introspection: drivers assert multi-stage serving actually happened
        self.served: Dict[int, Any] = {}

    # -- engine construction ------------------------------------------------
    def _make_engine(self, node: str, rng: LayerRange):
        n_paged = stage_num_paged_layers(self.cfg, rng)
        if not self.paged or n_paged == 0:
            # hybrid models can hand a node an all-SSM/MLA slice with no
            # paged block at all — that node serves dense even in paged mode
            return StageEngine(self.cfg, self.params, rng, self.ec,
                               rng_seed=self.rng_seed)
        rect = full_rectangle_pages(self.cfg, max_batch=self.ec.max_batch,
                                    max_len=self.ec.max_len,
                                    page_size=self.page_size,
                                    paged_layers=n_paged)
        if node in self.pool_pages:
            pages = self.pool_pages[node]
        else:
            pages = pages_for_vram(self.cfg,
                                   self.cluster.nodes[node].vram_bytes,
                                   page_size=self.page_size,
                                   layers_on_node=rng.num_layers,
                                   max_pages=rect)
            # floor: one full-budget request must always fit
            blocks = -(-self.ec.max_len // self.page_size)
            pages = max(pages, 1 + blocks * n_paged)
        return PagedStageEngine(self.cfg, self.params, rng, self.ec,
                                num_pages=pages, page_size=self.page_size,
                                interpret=self.interpret,
                                rng_seed=self.rng_seed)

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, fn: Callable[[], None]) -> None:
        self._eseq += 1
        heapq.heappush(self._events, (t, self._eseq, fn))

    def _send(self, src: str, dst: str, payload, nbytes: float,
              deliver: Callable[[Any], None]) -> None:
        self.transport.send(src, dst, payload, nbytes, deliver)

    def _act_bytes(self, n_tokens: int) -> float:
        elt = {"bfloat16": 2, "float32": 4}[self.cfg.param_dtype]
        return float(n_tokens * self.cfg.d_model * elt)

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if len(req.prompt) > self.ec.max_len:
            raise ValueError(f"prompt of {len(req.prompt)} tokens exceeds "
                             f"max_len {self.ec.max_len}; refusing to "
                             "truncate")
        req.submitted_s = time.time()
        self.queue.append(_Job(req))

    def run_until_done(self, max_iters: int = 100000) -> None:
        for _ in range(max_iters):
            if not (self.queue or self.jobs or self._events or self._ready):
                return
            if not self.step():
                raise RuntimeError(
                    "runtime stalled: queued requests cannot be admitted "
                    "(cluster slots/pools too small?)")
        raise RuntimeError(f"not done after {max_iters} iterations")

    def step(self) -> bool:
        """One runtime iteration: admit, drain deliveries due now, then one
        batched decode per node with resident stage-work.  Returns whether
        anything progressed."""
        progressed = self._admit()
        if self._events:
            self._now = max(self._now, self._events[0][0])
            while self._events and self._events[0][0] <= self._now + 1e-12:
                _, _, fn = heapq.heappop(self._events)
                fn()
                progressed = True
        for node in [n for n, v in self._ready.items() if v]:
            work = self._ready.pop(node)
            work = [w for w in work if w["job"].epoch == w["epoch"]]
            while work:
                self._decode_node(node, work[:self.ec.max_batch])
                work = work[self.ec.max_batch:]
                progressed = True
        self._sync_kv()
        return progressed

    # -- KV feedback --------------------------------------------------------
    def _sync_kv(self, capacities: bool = False) -> None:
        kv = self.scheduler.kv
        if kv is None:
            return
        for node, eng in self.engines.items():
            if capacities:
                kv.capacity_tokens[node] = float(eng.kv_tokens_capacity())
            kv.sync(node, float(eng.kv_tokens_used()))

    # -- admission ----------------------------------------------------------
    def _prefill_tokens(self, job: _Job) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after preemption/failover —
        all generated output but the last token (recompute; the last token
        restarts decode)."""
        prompt = np.asarray(job.req.prompt, np.int32)
        if len(job.req.output) > 1:
            prompt = np.concatenate(
                [prompt, np.asarray(job.req.output[:-1], np.int32)])
        return prompt

    def _admit(self) -> bool:
        progressed = False
        while self.queue:
            job = self.queue[0]
            if job.pipe is None:
                try:
                    job.pipe = self.scheduler.schedule()
                except RuntimeError:
                    break               # no route (mid-replan): wait
            S = len(self._prefill_tokens(job))
            need = min(S + 1, self.ec.max_len)
            taken: List[Tuple[str, int]] = []
            ok = True
            for st in job.pipe.stages:
                eng = self.engines.get(st.node)
                slot = eng.alloc_slot(job.req.request_id) if eng else None
                if slot is None or not eng.ensure(slot, need):
                    if slot is not None:
                        eng.free_slot(slot)
                    ok = False
                    break
                taken.append((st.node, slot))
            if not ok:
                for node, slot in taken:
                    self.engines[node].release(slot)
                break                   # FIFO: wait for running work to free
            self.queue.popleft()
            job.slots = dict(taken)
            job.pos = S
            job.seq = self._jseq
            self._jseq += 1
            self.jobs[job.req.request_id] = job
            self.served[job.req.request_id] = job.pipe
            self._dispatch_prefill(job)
            progressed = True
        return progressed

    def _dispatch_prefill(self, job: _Job) -> None:
        tokens = self._prefill_tokens(job)
        first = job.pipe.stages[0].node
        if self._chunked:
            chunk = tokens[:max(1, self.ec.prompt_len)]
            self._send(COORDINATOR, first, chunk,
                       len(chunk) * self.profile.token_bytes,
                       self._hop(job, 0, off=0))
        else:
            self._send(COORDINATOR, first, tokens,
                       len(tokens) * self.profile.token_bytes,
                       self._hop(job, 0, off=None))

    # -- prefill hops -------------------------------------------------------
    def _hop(self, job: _Job, si: int, off: Optional[int]
             ) -> Callable[[Any], None]:
        epoch = job.epoch
        return lambda payload: self._prefill_at(job, epoch, si, payload, off)

    def _prefill_at(self, job: _Job, epoch: int, si: int, x,
                    off: Optional[int]) -> None:
        if job.epoch != epoch:
            return                      # preempted/requeued mid-flight
        st = job.pipe.stages[si]
        eng = self.engines[st.node]
        slot = job.slots[st.node]
        entry = st.layers.start
        if self._chunked:
            out = eng.prefill_chunk(slot, x, entry, off)
        else:
            out = eng.prefill_stage(slot, x, entry)
        last = si == len(job.pipe.stages) - 1
        n_tok = (len(x) if entry == 0 else x.shape[1])
        if not last:
            nxt = job.pipe.stages[si + 1].node
            self._send(st.node, nxt, out, self._act_bytes(n_tok),
                       self._hop(job, si + 1, off))
        if self._chunked and si == 0:
            # stage 0 freed: stream the next chunk in behind this one
            tokens = self._prefill_tokens(job)
            nxt_off = off + n_tok
            if nxt_off < len(tokens):
                chunk = tokens[nxt_off:nxt_off + max(1, self.ec.prompt_len)]
                self._send(COORDINATOR, st.node, chunk,
                           len(chunk) * self.profile.token_bytes,
                           self._hop(job, 0, off=nxt_off))
        if last and (off is None or off + n_tok >= job.pos):
            # final chunk left the final stage: out is last-token logits
            if job.resumed:
                tok = job.req.output[-1]      # sampled before eviction
            else:
                tok = eng.sample(out, job.req.temperature)
            self._send(st.node, COORDINATOR, tok, self.profile.token_bytes,
                       lambda t: self._on_token(job, epoch, t, first=True))

    # -- token arrivals (coordinator) ----------------------------------------
    def _on_token(self, job: _Job, epoch: int, tok: int, first: bool) -> None:
        if job.epoch != epoch:
            return
        req = job.req
        reason = None
        if first:
            if not job.resumed:
                req.output.append(int(tok))
                req.first_token_s = time.time()
                self.tokens_produced += 1
                if int(tok) == self.ec.eos_token:
                    reason = "stop"
                elif req.max_new_tokens <= 1:
                    reason = "length"
                elif job.pos >= self.ec.max_len:
                    reason = "length"
        else:
            req.output.append(int(tok))
            self.tokens_produced += 1
            job.pos += 1
            if int(tok) == self.ec.eos_token:
                reason = "stop"
            elif len(req.output) >= req.max_new_tokens:
                reason = "length"
            elif job.pos >= self.ec.max_len:
                reason = "length"
        if reason is not None:
            self._complete(job, reason)
            return
        self._dispatch_decode(job)

    def _dispatch_decode(self, job: _Job) -> None:
        first = job.pipe.stages[0].node
        epoch = job.epoch
        tok = job.req.output[-1]
        self._send(COORDINATOR, first, tok, self.profile.token_bytes,
                   lambda t: self._ready[first].append(
                       dict(job=job, epoch=epoch, si=0, tok=int(t), h=None)))

    # -- decode (per-node continuous batching) -------------------------------
    def _decode_node(self, node: str, work: List[dict]) -> None:
        eng = self.engines.get(node)
        if eng is None:
            return
        # grow pools oldest-first; preempt the newest resident request
        # (pipeline-wide) when this node's pool runs dry
        for w in sorted(work, key=lambda w: w["job"].seq):
            job = w["job"]
            if job.epoch != w["epoch"]:
                continue
            while not eng.ensure(job.slots[node], job.pos + 1):
                live = [j for j in self.jobs.values() if node in j.slots]
                victim = max(live, key=lambda j: j.seq)
                self._preempt(victim)
                if victim is job:
                    break
        work = [w for w in work if w["job"].epoch == w["epoch"]]
        if not work:
            return
        items = [DecodeItem(slot=w["job"].slots[node], pos=w["job"].pos,
                            entry=w["job"].pipe.stages[w["si"]].layers.start,
                            token=w["tok"], h=w["h"]) for w in work]
        outs = eng.decode_stage(items)
        for w, out in zip(work, outs):
            job = w["job"]
            si = w["si"]
            epoch = w["epoch"]
            if si == len(job.pipe.stages) - 1:
                tok = eng.sample(out.logits, job.req.temperature)
                self._send(node, COORDINATOR, tok, self.profile.token_bytes,
                           lambda t, j=job, e=epoch:
                           self._on_token(j, e, t, first=False))
            else:
                nxt = job.pipe.stages[si + 1].node
                self._send(node, nxt, out.h, self._act_bytes(1),
                           lambda h, j=job, e=epoch, s=si + 1, n=nxt:
                           self._ready[n].append(
                               dict(job=j, epoch=e, si=s, tok=0, h=h)))

    # -- completion / preemption ---------------------------------------------
    def _release_all(self, job: _Job) -> None:
        for node, slot in job.slots.items():
            eng = self.engines.get(node)
            if eng is not None:
                eng.release(slot)
        job.slots = {}

    def _complete(self, job: _Job, reason: str) -> None:
        req = job.req
        req.done = True
        req.finish_reason = reason
        req.finished_s = time.time()
        self._release_all(job)
        self.jobs.pop(req.request_id, None)
        self.completed += 1

    def _preempt(self, job: _Job) -> None:
        """Pool exhausted: evict pipeline-wide, keep generated tokens, requeue
        at the front (recompute-on-readmit, same pipeline)."""
        self._requeue(job, clear_pipe=False)

    # -- failover ------------------------------------------------------------
    def fail_node(self, name: str) -> None:
        """Kill a node's engine; every request whose pipeline crossed it is
        requeued (its KV on survivors released) pending a replanned pipeline."""
        self.engines.pop(name, None)
        for job in list(self.jobs.values()):
            if name in job.pipe.nodes:
                self._requeue(job, clear_pipe=True)
        for job in self.queue:
            if job.pipe is not None and name in job.pipe.nodes:
                job.pipe = None

    def _requeue(self, job: _Job, clear_pipe: bool) -> None:
        job.epoch += 1
        self._release_all(job)
        if clear_pipe:
            job.pipe = None
        self.jobs.pop(job.req.request_id, None)
        job.req.preemptions += 1
        self.queue.appendleft(job)

    def apply_plan(self, plan) -> None:
        """Adopt a replanned placement: rebuild engines whose slice changed
        (requeueing their resident requests), swap IWRR weights in place when
        the placement survived, else install a fresh scheduler, and re-sync
        true pool occupancy into the KV estimator."""
        new_assign = plan.placement.assignment
        for node in [n for n in self.engines if n not in new_assign]:
            self.fail_node(node)
        changed = set()
        for node, rng in sorted(new_assign.items()):
            if node in self.engines and self.placement.assignment.get(node) == rng:
                continue
            changed.add(node)
            for job in list(self.jobs.values()):
                if node in job.slots:
                    self._requeue(job, clear_pipe=True)
            self.engines[node] = self._make_engine(node, rng)
        # queued jobs (e.g. preempted ones holding their old pipeline) whose
        # cached pipeline crosses a rebuilt node would execute stale layer
        # ranges — force them to reschedule
        for job in self.queue:
            if job.pipe is not None and changed.intersection(job.pipe.nodes):
                job.pipe = None
        same = self.placement.assignment == new_assign
        self.cluster = plan.cluster
        self.placement = plan.placement
        self.profile = plan.model
        if same and self.scheduler.placement.assignment == new_assign:
            self.scheduler.update_weights(plan.flows)
        else:
            kv_old = self.scheduler.kv
            self.scheduler = plan.make_scheduler()
            if self.scheduler.kv is not None and kv_old is not None:
                self.scheduler.kv.high_water = kv_old.high_water
        self._sync_kv(capacities=True)

    # -- introspection --------------------------------------------------------
    def pool_pages_used(self) -> Dict[str, int]:
        return {n: e.pool.used for n, e in self.engines.items()
                if isinstance(e, PagedStageEngine)}
