"""Per-node stage engines: the execution half of a Helix compute node.

``Engine``/``PagedEngine`` (engine.py) own the whole request lifecycle for a
single full-model node.  A *stage engine* is the same machinery split at the
stage boundary: it holds only the params (``models.stage.stage_params``) and
KV for one node's assigned ``LayerRange`` and exposes a stage-level API the
``ClusterRuntime`` drives:

  prefill_stage(slot, x, entry)    prompt pass for one request; ``x`` is
                                   token ids (entry layer 0) or incoming
                                   activations; returns activations, or
                                   last-token logits at the final stage
  prefill_chunk(slot, x, entry, start)   chunked paged prefill (all-paged)
  decode_stage(items)              ONE batched decode step over whatever
                                   stage-work is resident this iteration —
                                   per-node continuous batching; items may
                                   mix requests entering at different layers
  sample(logits, temperature)      final-stage token sampling

Slot mechanics: caches (and the paged pool's block table) carry
``max_batch + 1`` rows; the extra row is scratch — decode batches are padded
to a fixed width with scratch rows so every step hits one compiled program,
and scratch writes land in cache rows (or page 0) nothing ever reads.

The paged engine's ``PagePool`` is sized from the node's own VRAM with the
page cost of its *local* paged-layer count, so memory heterogeneity shows up
as genuinely different pool depths per node.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.placement import LayerRange
from ..models.paged import all_blocks_paged, is_paged_block
from ..models.stage import (stage_absorb_dense_prefill, stage_blocks,
                            stage_cache_init, stage_cache_init_paged,
                            stage_decode, stage_decode_paged,
                            stage_num_paged_layers, stage_params,
                            stage_prefill, stage_prefill_chunk_paged)
from .engine import EngineConfig, _active_blocks_bucket
from .kv_pool import PagePool, full_rectangle_pages
from .sampling import sample_token


@dataclasses.dataclass
class DecodeItem:
    """One request's decode-step input resident at a node this iteration."""

    slot: int
    pos: int                      # absolute position of the token/activation
    entry: int                    # request's entry layer at this node
    token: int = 0                # consumed only when entry == 0
    h: Optional[np.ndarray] = None  # (1, 1, d) incoming activations


@dataclasses.dataclass
class DecodeOut:
    h: Optional[np.ndarray]       # (1, 1, d) outgoing activations
    logits: Optional[np.ndarray]  # (V,) — final stage only


class _StageEngineBase:
    """Slot bookkeeping shared by the dense and paged stage engines."""

    def __init__(self, cfg: ModelConfig, params, layers: LayerRange,
                 engine_cfg: EngineConfig, rng_seed: int = 0):
        self.cfg = cfg
        self.layers = layers
        self.ec = engine_cfg
        self.sparams = stage_params(cfg, params, layers)
        self.is_first = layers.start == 0
        self.is_last = layers.end == cfg.num_layers
        self.slots: List[Optional[int]] = [None] * engine_cfg.max_batch
        self._scratch = engine_cfg.max_batch   # padding row, never allocated
        self._rng = np.random.RandomState(rng_seed)

    # -- slots ----------------------------------------------------------
    def alloc_slot(self, request_id: int) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                self.slots[i] = request_id
                return i
        return None

    def free_slot(self, slot: int) -> None:
        self.slots[slot] = None

    @property
    def free_slots(self) -> int:
        return sum(r is None for r in self.slots)

    # -- sampling (final stage) -----------------------------------------
    def sample(self, logits: np.ndarray, temperature: float) -> int:
        return int(sample_token(logits, temperature, self._rng))

    # -- KV feedback -----------------------------------------------------
    def kv_tokens_used(self) -> int:
        raise NotImplementedError

    def kv_tokens_capacity(self) -> int:
        raise NotImplementedError

    def pool_used(self) -> Optional[int]:
        """Allocated page count, or None for engines without a page pool —
        uniform across local and remote engines so the runtime's drain
        checks work over RPC."""
        pool = getattr(self, "pool", None)
        return pool.used if pool is not None else None

    # -- batch assembly ---------------------------------------------------
    def _assemble(self, items: List[DecodeItem]):
        B = self.ec.max_batch + 1
        if not 0 < len(items) <= self.ec.max_batch:
            raise ValueError(f"{len(items)} decode items for "
                             f"{self.ec.max_batch} slots")
        # one batched step gathers/scatters each cache row once, so a batch
        # holding tokens t and t+1 of one request would lose t's KV write.
        # The runtime upholds this by construction (pass t+1 is only born
        # when pass t exits the final stage, so one pass per request is in
        # the stages at a time); this guard is the invariant check — true
        # multi-token speculation would need position-ordered sub-batches.
        slots = [it.slot for it in items]
        if len(set(slots)) != len(slots):
            raise ValueError(
                "duplicate cache slot in one decode batch: in-flight tokens "
                "of a request must decode in separate, position-ordered "
                f"batches (slots={slots})")
        d = self.cfg.d_model
        idx = np.full((B,), self._scratch, np.int32)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        entry = np.full((B,), self.layers.end, np.int32)  # pads: all masked
        h_in = np.zeros((B, 1, d), np.float32)
        for i, it in enumerate(items):
            idx[i] = it.slot
            tok[i] = it.token
            pos[i] = it.pos
            entry[i] = it.entry
            if it.h is not None:
                h_in[i] = it.h
        return (jnp.asarray(idx), jnp.asarray(tok), jnp.asarray(pos),
                jnp.asarray(entry), jnp.asarray(h_in))

    def _emit(self, items: List[DecodeItem], h_out, logits) -> List[DecodeOut]:
        h_np = np.asarray(h_out)
        l_np = np.asarray(logits) if logits is not None else None
        return [DecodeOut(h=h_np[i:i + 1],
                          logits=l_np[i] if l_np is not None else None)
                for i in range(len(items))]


def _splice(full, one, slot: int):
    """Copy a batch-1 cache leaf into row ``slot`` of the engine leaf."""
    return full.at[slot].set(one[0])


class StageEngine(_StageEngineBase):
    """Dense per-slot caches over the node's layer slice."""

    def __init__(self, cfg: ModelConfig, params, layers: LayerRange,
                 engine_cfg: EngineConfig, rng_seed: int = 0):
        super().__init__(cfg, params, layers, engine_cfg, rng_seed)
        ec = engine_cfg
        self.caches = stage_cache_init(cfg, layers, ec.max_batch + 1,
                                       ec.max_len)
        self._prefill = jax.jit(
            lambda sp, x, entry: stage_prefill(cfg, sp, layers, x, entry,
                                               max_len=ec.max_len),
            static_argnums=(2,))

        def decode_fn(sp, caches, tok, h_in, entry, pos, idx):
            cg = jax.tree.map(lambda c: c[idx], caches)
            h, logits, nc = stage_decode(cfg, sp, layers, tok, h_in, entry,
                                         cg, pos)
            new = jax.tree.map(lambda full, n: full.at[idx].set(n),
                               caches, nc)
            return h, logits, new

        self._decode = jax.jit(decode_fn)
        self._active_tokens = np.zeros((ec.max_batch,), np.int64)

    def prefill_stage(self, slot: int, x, entry: int):
        """Prompt pass for one request.  x: (S,) int token ids when
        ``entry == 0`` else (1, S, d) activations.  Returns (1, S, d)
        activations, or (V,) last-token logits at the final stage."""
        if entry == 0:
            S = len(x)
            xin = jnp.asarray(np.asarray(x, np.int32))[None, :]
        else:
            S = x.shape[1]
            xin = jnp.asarray(x)
        out, caches1 = self._prefill(self.sparams, xin, entry)
        self.caches = jax.tree.map(
            lambda full, one: _splice(full, one, slot), self.caches, caches1)
        self._active_tokens[slot] = S
        return np.asarray(out)[0] if self.is_last else np.asarray(out)

    def decode_stage(self, items: List[DecodeItem]) -> List[DecodeOut]:
        idx, tok, pos, entry, h_in = self._assemble(items)
        h, logits, self.caches = self._decode(self.sparams, self.caches, tok,
                                              h_in, entry, pos, idx)
        for it in items:
            self._active_tokens[it.slot] = it.pos + 1
        return self._emit(items, h, logits)

    def release(self, slot: int) -> None:
        self._active_tokens[slot] = 0
        self.free_slot(slot)

    def ensure(self, slot: int, tokens: int) -> bool:
        return tokens <= self.ec.max_len   # rectangle is pre-reserved

    def kv_tokens_used(self) -> int:
        return int(self._active_tokens.sum())

    def kv_tokens_capacity(self) -> int:
        return self.ec.max_batch * self.ec.max_len

    # -- KV handoff (disaggregated prefill -> decode replicas) -----------
    def export_kv(self, slot: int, tokens: int, layers: List[int]):
        """Snapshot this slot's filled caches for the given *global* layer
        indices as a wire tree ``{layer: cache subtree}`` (batchless
        leaves) — the decode replica splices them with ``import_kv``."""
        want = set(layers)
        out = {}
        for (l, _), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            if l in want:
                out[l] = jax.tree.map(lambda a: np.asarray(a[slot]), c)
        return out

    def import_kv(self, slot: int, tokens: int, payload) -> None:
        new = []
        for (l, _), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            one = payload.get(l)
            if one is None:
                new.append(c)
            else:
                new.append(jax.tree.map(
                    lambda full, a: full.at[slot].set(jnp.asarray(a)),
                    c, one))
        self.caches = new
        self._active_tokens[slot] = tokens


class PagedStageEngine(_StageEngineBase):
    """Paged-KV stage engine: the node's paged blocks share one ``PagePool``
    sized from its VRAM; everything else keeps dense fallback caches."""

    def __init__(self, cfg: ModelConfig, params, layers: LayerRange,
                 engine_cfg: EngineConfig, *, num_pages: Optional[int] = None,
                 page_size: int = 16, kv_dtype: Optional[str] = None,
                 interpret: Optional[bool] = None, rng_seed: int = 0):
        super().__init__(cfg, params, layers, engine_cfg, rng_seed)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        ec = engine_cfg
        self.n_paged = stage_num_paged_layers(cfg, layers)
        if self.n_paged == 0:
            raise ValueError(f"slice {layers} of {cfg.name} holds no paged "
                             "blocks; use the dense StageEngine")
        self._chunked = all_blocks_paged(cfg)
        if num_pages is None:
            num_pages = full_rectangle_pages(cfg, max_batch=ec.max_batch,
                                             max_len=ec.max_len,
                                             page_size=page_size,
                                             paged_layers=self.n_paged)
        # the scratch slot never allocates, so the pool only needs capacity
        # for the real max_batch; the extra table column stays on page 0
        self.pool = PagePool(cfg, num_pages=num_pages, page_size=page_size,
                             max_batch=ec.max_batch + 1, max_seq_len=ec.max_len,
                             paged_layers=self.n_paged, kv_dtype=kv_dtype)
        self.caches = stage_cache_init_paged(cfg, layers, ec.max_batch + 1,
                                             ec.max_len)
        on_cpu = jax.default_backend() == "cpu"
        if self._chunked:
            def _chunk(sp, x, entry, start, kp, vp, ks, vs, tb, *,
                       n_act: int):
                return stage_prefill_chunk_paged(
                    cfg, sp, layers, x, entry, start, kp, vp, tb,
                    k_scales=ks, v_scales=vs, active_blocks=n_act)
            self._prefill_chunk = jax.jit(
                _chunk, static_argnums=(2,), static_argnames=("n_act",),
                donate_argnums=() if on_cpu else (4, 5, 6, 7))
        else:
            self._prefill_one = jax.jit(
                lambda sp, x, entry: stage_prefill(cfg, sp, layers, x, entry,
                                                   max_len=ec.max_len),
                static_argnums=(2,))

        def decode_fn(sp, caches, tok, h_in, entry, pos, idx, kp, vp, ks, vs,
                      tables):
            cg = jax.tree.map(lambda c: c[idx], caches)
            tb = tables[:, idx]
            h, logits, nc, kp, vp, ks, vs = stage_decode_paged(
                cfg, sp, layers, tok, h_in, entry, cg, pos, kp, vp, tb,
                k_scales=ks, v_scales=vs, interpret=interpret)
            new = jax.tree.map(lambda full, n: full.at[idx].set(n),
                               caches, nc)
            return h, logits, new, kp, vp, ks, vs

        self._decode = jax.jit(decode_fn,
                               donate_argnums=() if on_cpu else (7, 8, 9, 10))

    # -- pool ------------------------------------------------------------
    def ensure(self, slot: int, tokens: int) -> bool:
        return self.pool.ensure(slot, tokens)

    def release(self, slot: int) -> None:
        self.pool.release(slot)
        self.free_slot(slot)

    def kv_tokens_used(self) -> int:
        return self.pool.tokens_used

    def kv_tokens_capacity(self) -> int:
        return self.pool.tokens_capacity

    # -- prefill ---------------------------------------------------------
    def prefill_chunk(self, slot: int, x, entry: int, start: int):
        """One prompt chunk through the slice (all-paged stacks).  x: (C,)
        tokens or (1, C, d) activations.  Returns chunk activations
        (1, C, d), or last-token logits (V,) at the final stage."""
        if entry == 0:
            xin = jnp.asarray(np.asarray(x, np.int32))[None, :]
        else:
            xin = jnp.asarray(x)
        C = xin.shape[1]
        tb = jnp.asarray(self.pool.table[:, slot:slot + 1])
        n_act = _active_blocks_bucket(start + C, self.pool.page,
                                      self.pool.blocks_per_seq)
        pool = self.pool
        out, pool.k, pool.v, pool.k_scales, pool.v_scales = \
            self._prefill_chunk(
                self.sparams, xin, entry, jnp.asarray([start], jnp.int32),
                pool.k, pool.v, pool.k_scales, pool.v_scales, tb,
                n_act=n_act)
        return np.asarray(out)[0] if self.is_last else np.asarray(out)

    def prefill_stage(self, slot: int, x, entry: int):
        """Single-shot prompt pass (hybrid stacks): dense prefill of the
        slice, then the paged blocks' K/V is scattered into this slot's
        pages and the dense fallback caches spliced into the slot."""
        if self._chunked:
            raise RuntimeError("all-paged slice: drive prefill_chunk instead")
        if entry == 0:
            S = len(x)
            xin = jnp.asarray(np.asarray(x, np.int32))[None, :]
        else:
            S = x.shape[1]
            xin = jnp.asarray(x)
        out, caches1 = self._prefill_one(self.sparams, xin, entry)
        pool = self.pool
        caches1, pool.k, pool.v, pool.k_scales, pool.v_scales = \
            stage_absorb_dense_prefill(
                self.cfg, self.layers, caches1, pool.k, pool.v,
                pool.table, slot, S, pool.page,
                k_scales=pool.k_scales, v_scales=pool.v_scales)
        self.caches = jax.tree.map(
            lambda full, one: _splice(full, one, slot), self.caches, caches1)
        return np.asarray(out)[0] if self.is_last else np.asarray(out)

    # -- KV handoff (disaggregated prefill -> decode replicas) -----------
    def export_kv(self, slot: int, tokens: int, layers: List[int]):
        """Snapshot this slot's KV for the given *global* layer indices:
        paged blocks ship their live pages (int8 pages + per-page scales
        travel as-is, no requantization), hybrid dense blocks ship their
        cache subtree."""
        want = set(layers)
        nb = -(-tokens // self.pool.page)
        out = {}
        li = 0
        for (l, b), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            paged = is_paged_block(self.cfg, b)
            if l in want:
                if paged:
                    pids = self.pool.table[li, slot, :nb]
                    p = {"k": np.asarray(self.pool.k[pids]),
                         "v": np.asarray(self.pool.v[pids])}
                    if self.pool.quantized:
                        p["ks"] = np.asarray(self.pool.k_scales[pids])
                        p["vs"] = np.asarray(self.pool.v_scales[pids])
                    out[l] = p
                else:
                    out[l] = jax.tree.map(lambda a: np.asarray(a[slot]), c)
            if paged:
                li += 1
        return out

    def import_kv(self, slot: int, tokens: int, payload) -> None:
        """Scatter a shipped KV snapshot into this slot.  The runtime
        reserves the slot's blocks at admission; ``ensure`` here is a
        defensive no-op growth in the common case."""
        if not self.pool.ensure(slot, tokens):
            raise RuntimeError(
                f"import_kv: pool cannot hold {tokens} tokens in slot "
                f"{slot}")
        nb = -(-tokens // self.pool.page)
        pool = self.pool
        new = []
        li = 0
        for (l, b), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            paged = is_paged_block(self.cfg, b)
            p = payload.get(l)
            if p is None:
                new.append(c)
            elif paged:
                pids = jnp.asarray(pool.table[li, slot, :nb])
                pool.k = pool.k.at[pids].set(
                    jnp.asarray(p["k"]).astype(pool.k.dtype))
                pool.v = pool.v.at[pids].set(
                    jnp.asarray(p["v"]).astype(pool.v.dtype))
                if pool.quantized:
                    pool.k_scales = pool.k_scales.at[pids].set(
                        jnp.asarray(p["ks"]))
                    pool.v_scales = pool.v_scales.at[pids].set(
                        jnp.asarray(p["vs"]))
                new.append(c)
            else:
                new.append(jax.tree.map(
                    lambda full, a: full.at[slot].set(jnp.asarray(a)),
                    c, p))
            if paged:
                li += 1
        self.caches = new

    # -- decode ----------------------------------------------------------
    def decode_stage(self, items: List[DecodeItem]) -> List[DecodeOut]:
        idx, tok, pos, entry, h_in = self._assemble(items)
        tables = jnp.asarray(self.pool.table)
        pool = self.pool
        (h, logits, self.caches, pool.k, pool.v,
         pool.k_scales, pool.v_scales) = self._decode(
            self.sparams, self.caches, tok, h_in, entry, pos, idx,
            pool.k, pool.v, pool.k_scales, pool.v_scales, tables)
        return self._emit(items, h, logits)


def make_stage_engine(cfg: ModelConfig, params, layers: LayerRange,
                      engine_cfg: EngineConfig, *, paged: bool = True,
                      **kw) -> _StageEngineBase:
    if paged:
        return PagedStageEngine(cfg, params, layers, engine_cfg, **kw)
    kw.pop("num_pages", None)
    kw.pop("page_size", None)
    kw.pop("kv_dtype", None)
    kw.pop("interpret", None)
    return StageEngine(cfg, params, layers, engine_cfg, **kw)
