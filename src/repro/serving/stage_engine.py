"""Per-node stage engines: the execution half of a Helix compute node.

``Engine``/``PagedEngine`` (engine.py) own the whole request lifecycle for a
single full-model node.  A *stage engine* is the same machinery split at the
stage boundary: it holds only the params (``models.stage.stage_params``) and
KV for one node's assigned ``LayerRange`` and exposes a stage-level API the
``ClusterRuntime`` drives:

  prefill_stage(slot, x, entry)    prompt pass for one request; ``x`` is
                                   token ids (entry layer 0) or incoming
                                   activations; returns activations, or
                                   last-token logits at the final stage
  prefill_chunk(slot, x, entry, start)   chunked paged prefill (all-paged)
  decode_stage(items)              ONE batched decode step over whatever
                                   stage-work is resident this iteration —
                                   per-node continuous batching; items may
                                   mix requests entering at different layers
  sample(logits, temperature)      final-stage token sampling

Slot mechanics: caches (and the paged pool's block table) carry
``max_batch + 1`` rows; the extra row is scratch — decode batches are padded
to a fixed width with scratch rows so every step hits one compiled program,
and scratch writes land in cache rows (or page 0) nothing ever reads.

The paged engine's ``PagePool`` is sized from the node's own VRAM with the
page cost of its *local* paged-layer count, so memory heterogeneity shows up
as genuinely different pool depths per node.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.placement import LayerRange
from ..models.paged import all_blocks_paged, is_paged_block
from ..models.stage import (stage_absorb_dense_prefill, stage_blocks,
                            stage_cache_init, stage_cache_init_paged,
                            stage_decode, stage_decode_paged,
                            stage_num_paged_layers, stage_params,
                            stage_prefill, stage_prefill_chunk_paged)
from .engine import EngineConfig, _active_blocks_bucket
from .kv_pool import PagePool, full_rectangle_pages
from .sampling import sample_token


@dataclasses.dataclass
class DecodeItem:
    """One request's decode-step input resident at a node this iteration.

    A single-token item carries ``token`` (entry 0) or ``h`` of shape
    (1, 1, d).  A speculative verify pass carries ``tokens`` — the last
    confirmed token followed by the draft proposals, consumed at positions
    ``pos .. pos+n-1`` — or, downstream of the entry stage, ``h`` of shape
    (n, 1, d).  The engines run multi-token items as ``n`` position-ordered
    sub-steps, so the KV write history (and on int8 pools the per-page
    requantization history) is byte-identical to ``n`` ordinary decode
    steps — acceptance rate can only change speed, never bytes."""

    slot: int
    pos: int                      # absolute position of the FIRST token
    entry: int                    # request's entry layer at this node
    token: int = 0                # consumed only when entry == 0
    h: Optional[np.ndarray] = None  # (n, 1, d) incoming activations
    tokens: Optional[Sequence[int]] = None  # verify pass (entry == 0 only)

    @property
    def n(self) -> int:
        """Token count of this item (1 for ordinary decode)."""
        if self.tokens is not None:
            return len(self.tokens)
        if self.h is not None and getattr(self.h, "ndim", 0) == 3:
            return int(self.h.shape[0])
        return 1

    def substep(self, s: int) -> "DecodeItem":
        """The single-token item for sub-step ``s`` (position ``pos + s``)."""
        return DecodeItem(
            slot=self.slot, pos=self.pos + s, entry=self.entry,
            token=int(self.tokens[s]) if self.tokens is not None
            else self.token,
            h=None if self.h is None else np.asarray(self.h[s:s + 1]))


@dataclasses.dataclass
class DecodeOut:
    h: Optional[np.ndarray]       # (n, 1, d) outgoing activations
    logits: Optional[np.ndarray]  # (V,) — or (n, V) for a verify pass


class _StageEngineBase:
    """Slot bookkeeping shared by the dense and paged stage engines."""

    def __init__(self, cfg: ModelConfig, params, layers: LayerRange,
                 engine_cfg: EngineConfig, rng_seed: int = 0):
        self.cfg = cfg
        self.layers = layers
        self.ec = engine_cfg
        self.sparams = stage_params(cfg, params, layers)
        self.is_first = layers.start == 0
        self.is_last = layers.end == cfg.num_layers
        self.slots: List[Optional[int]] = [None] * engine_cfg.max_batch
        self._scratch = engine_cfg.max_batch   # padding row, never allocated
        self._rng = np.random.RandomState(rng_seed)

    # -- slots ----------------------------------------------------------
    def alloc_slot(self, request_id: int) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                self.slots[i] = request_id
                return i
        return None

    def free_slot(self, slot: int) -> None:
        self.slots[slot] = None

    @property
    def free_slots(self) -> int:
        return sum(r is None for r in self.slots)

    # -- sampling (final stage) -----------------------------------------
    def sample(self, logits: np.ndarray, temperature: float) -> int:
        return int(sample_token(logits, temperature, self._rng))

    # -- KV feedback -----------------------------------------------------
    def kv_tokens_used(self) -> int:
        raise NotImplementedError

    def kv_tokens_capacity(self) -> int:
        raise NotImplementedError

    def pool_used(self) -> Optional[int]:
        """Allocated page count, or None for engines without a page pool —
        uniform across local and remote engines so the runtime's drain
        checks work over RPC."""
        pool = getattr(self, "pool", None)
        return pool.used if pool is not None else None

    # -- batch assembly ---------------------------------------------------
    def _assemble(self, items: List[DecodeItem]):
        B = self.ec.max_batch + 1
        if not 0 < len(items) <= self.ec.max_batch:
            raise ValueError(f"{len(items)} decode items for "
                             f"{self.ec.max_batch} slots")
        # one batched step gathers/scatters each cache row once, so a batch
        # holding tokens t and t+1 of one request would lose t's KV write.
        # Multi-token speculation is handled above this guard: decode_stage
        # splits verify items into position-ordered sub-batches, each of
        # which reaches _assemble with one token per request — so within
        # any assembled batch slots are still unique by construction.
        slots = [it.slot for it in items]
        if len(set(slots)) != len(slots):
            raise ValueError(
                "duplicate cache slot in one decode batch: in-flight tokens "
                "of a request must decode in separate, position-ordered "
                f"batches (slots={slots})")
        d = self.cfg.d_model
        idx = np.full((B,), self._scratch, np.int32)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        entry = np.full((B,), self.layers.end, np.int32)  # pads: all masked
        h_in = np.zeros((B, 1, d), np.float32)
        for i, it in enumerate(items):
            idx[i] = it.slot
            tok[i] = it.token
            pos[i] = it.pos
            entry[i] = it.entry
            if it.h is not None:
                h_in[i] = it.h
        return (jnp.asarray(idx), jnp.asarray(tok), jnp.asarray(pos),
                jnp.asarray(entry), jnp.asarray(h_in))

    # -- decode orchestration ---------------------------------------------
    def _decode_step(self, items: List[DecodeItem]):
        """One batched single-token decode step.  Returns (h, logits) as
        numpy arrays of shape (len(items), 1, d) and (len(items), V) (or
        None off the final stage)."""
        raise NotImplementedError

    def _spec_begin(self, it: DecodeItem) -> None:
        """Hook before a multi-token item's first sub-step (clears any
        stale rollback snapshots for the slot)."""

    def _snap_substep(self, it: DecodeItem, s: int) -> None:
        """Hook after a multi-token item's sub-step ``s`` committed its KV
        write — int8 pools snapshot the frontier page for exact rollback."""

    def rollback(self, slot: int, tokens: int) -> None:
        """Forget ``slot``'s rows >= ``tokens`` (rejected draft suffix)."""
        raise NotImplementedError

    def decode_stage(self, items: List[DecodeItem]) -> List[DecodeOut]:
        """ONE batched decode step over the stage-work resident this
        iteration.  Multi-token (speculative verify) items are run as
        position-ordered sub-batches: sub-step ``s`` batches the s-th token
        of every item that has one, so a request's token at ``pos+s``
        decodes strictly after its KV write at ``pos+s-1`` — the same write
        history as ``n`` ordinary decode steps, which is what keeps greedy
        speculative output byte-identical (dense, paged and int8 alike)."""
        n = max(it.n for it in items)
        if n == 1:
            # normalize length-1 ``tokens`` items into plain token items
            items = [it if it.tokens is None else it.substep(0)
                     for it in items]
            h, l = self._decode_step(items)
            return [DecodeOut(h=h[i:i + 1],
                              logits=l[i] if l is not None else None)
                    for i in range(len(items))]
        for it in items:
            if it.n > 1:
                self._spec_begin(it)
        hs: List[List[np.ndarray]] = [[] for _ in items]
        ls: List[List[np.ndarray]] = [[] for _ in items]
        for s in range(n):
            sel = [i for i, it in enumerate(items) if s < it.n]
            sub = [items[i].substep(s) for i in sel]
            h, l = self._decode_step(sub)
            for k, i in enumerate(sel):
                hs[i].append(h[k:k + 1])
                if l is not None:
                    ls[i].append(l[k])
                if items[i].n > 1:
                    self._snap_substep(items[i], s)
        outs = []
        for i, it in enumerate(items):
            if it.n == 1:   # keep single-token output shapes: (1,1,d) / (V,)
                outs.append(DecodeOut(h=hs[i][0],
                                      logits=ls[i][0] if ls[i] else None))
            else:
                outs.append(DecodeOut(
                    h=np.concatenate(hs[i], axis=0),
                    logits=np.stack(ls[i], axis=0) if ls[i] else None))
        return outs


def _splice(full, one, slot: int):
    """Copy a batch-1 cache leaf into row ``slot`` of the engine leaf."""
    return full.at[slot].set(one[0])


class StageEngine(_StageEngineBase):
    """Dense per-slot caches over the node's layer slice."""

    def __init__(self, cfg: ModelConfig, params, layers: LayerRange,
                 engine_cfg: EngineConfig, rng_seed: int = 0):
        super().__init__(cfg, params, layers, engine_cfg, rng_seed)
        ec = engine_cfg
        self.caches = stage_cache_init(cfg, layers, ec.max_batch + 1,
                                       ec.max_len)
        self._prefill = jax.jit(
            lambda sp, x, entry: stage_prefill(cfg, sp, layers, x, entry,
                                               max_len=ec.max_len),
            static_argnums=(2,))

        def decode_fn(sp, caches, tok, h_in, entry, pos, idx):
            cg = jax.tree.map(lambda c: c[idx], caches)
            h, logits, nc = stage_decode(cfg, sp, layers, tok, h_in, entry,
                                         cg, pos)
            new = jax.tree.map(lambda full, n: full.at[idx].set(n),
                               caches, nc)
            return h, logits, new

        self._decode = jax.jit(decode_fn)
        self._active_tokens = np.zeros((ec.max_batch,), np.int64)

    def prefill_stage(self, slot: int, x, entry: int):
        """Prompt pass for one request.  x: (S,) int token ids when
        ``entry == 0`` else (1, S, d) activations.  Returns (1, S, d)
        activations, or (V,) last-token logits at the final stage."""
        if entry == 0:
            S = len(x)
            xin = jnp.asarray(np.asarray(x, np.int32))[None, :]
        else:
            S = x.shape[1]
            xin = jnp.asarray(x)
        out, caches1 = self._prefill(self.sparams, xin, entry)
        self.caches = jax.tree.map(
            lambda full, one: _splice(full, one, slot), self.caches, caches1)
        self._active_tokens[slot] = S
        return np.asarray(out)[0] if self.is_last else np.asarray(out)

    def _decode_step(self, items: List[DecodeItem]):
        idx, tok, pos, entry, h_in = self._assemble(items)
        h, logits, self.caches = self._decode(self.sparams, self.caches, tok,
                                              h_in, entry, pos, idx)
        for it in items:
            self._active_tokens[it.slot] = it.pos + 1
        return (np.asarray(h),
                np.asarray(logits) if logits is not None else None)

    def rollback(self, slot: int, tokens: int) -> None:
        """Dense caches are positional and attention masks rows >= pos, so
        forgetting a rejected draft suffix is pure bookkeeping — relaunched
        tokens overwrite their rows in place."""
        self._active_tokens[slot] = tokens

    def release(self, slot: int) -> None:
        self._active_tokens[slot] = 0
        self.free_slot(slot)

    def ensure(self, slot: int, tokens: int) -> bool:
        return tokens <= self.ec.max_len   # rectangle is pre-reserved

    def kv_tokens_used(self) -> int:
        return int(self._active_tokens.sum())

    def kv_tokens_capacity(self) -> int:
        return self.ec.max_batch * self.ec.max_len

    # -- KV handoff (disaggregated prefill -> decode replicas) -----------
    def export_kv(self, slot: int, tokens: int, layers: List[int]):
        """Snapshot this slot's filled caches for the given *global* layer
        indices as a wire tree ``{layer: cache subtree}`` (batchless
        leaves) — the decode replica splices them with ``import_kv``."""
        want = set(layers)
        out = {}
        for (l, _), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            if l in want:
                out[l] = jax.tree.map(lambda a: np.asarray(a[slot]), c)
        return out

    def import_kv(self, slot: int, tokens: int, payload) -> None:
        new = []
        for (l, _), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            one = payload.get(l)
            if one is None:
                new.append(c)
            else:
                new.append(jax.tree.map(
                    lambda full, a: full.at[slot].set(jnp.asarray(a)),
                    c, one))
        self.caches = new
        self._active_tokens[slot] = tokens


class PagedStageEngine(_StageEngineBase):
    """Paged-KV stage engine: the node's paged blocks share one ``PagePool``
    sized from its VRAM; everything else keeps dense fallback caches."""

    def __init__(self, cfg: ModelConfig, params, layers: LayerRange,
                 engine_cfg: EngineConfig, *, num_pages: Optional[int] = None,
                 page_size: int = 16, kv_dtype: Optional[str] = None,
                 interpret: Optional[bool] = None, rng_seed: int = 0):
        super().__init__(cfg, params, layers, engine_cfg, rng_seed)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        ec = engine_cfg
        self.n_paged = stage_num_paged_layers(cfg, layers)
        if self.n_paged == 0:
            raise ValueError(f"slice {layers} of {cfg.name} holds no paged "
                             "blocks; use the dense StageEngine")
        self._chunked = all_blocks_paged(cfg)
        if num_pages is None:
            num_pages = full_rectangle_pages(cfg, max_batch=ec.max_batch,
                                             max_len=ec.max_len,
                                             page_size=page_size,
                                             paged_layers=self.n_paged)
        # the scratch slot never allocates, so the pool only needs capacity
        # for the real max_batch; the extra table column stays on page 0
        self.pool = PagePool(cfg, num_pages=num_pages, page_size=page_size,
                             max_batch=ec.max_batch + 1, max_seq_len=ec.max_len,
                             paged_layers=self.n_paged, kv_dtype=kv_dtype)
        self.caches = stage_cache_init_paged(cfg, layers, ec.max_batch + 1,
                                             ec.max_len)
        on_cpu = jax.default_backend() == "cpu"
        if self._chunked:
            def _chunk(sp, x, entry, start, kp, vp, ks, vs, tb, *,
                       n_act: int):
                return stage_prefill_chunk_paged(
                    cfg, sp, layers, x, entry, start, kp, vp, tb,
                    k_scales=ks, v_scales=vs, active_blocks=n_act)
            self._prefill_chunk = jax.jit(
                _chunk, static_argnums=(2,), static_argnames=("n_act",),
                donate_argnums=() if on_cpu else (4, 5, 6, 7))
        else:
            self._prefill_one = jax.jit(
                lambda sp, x, entry: stage_prefill(cfg, sp, layers, x, entry,
                                                   max_len=ec.max_len),
                static_argnums=(2,))

        def decode_fn(sp, caches, tok, h_in, entry, pos, idx, kp, vp, ks, vs,
                      tables):
            cg = jax.tree.map(lambda c: c[idx], caches)
            tb = tables[:, idx]
            h, logits, nc, kp, vp, ks, vs = stage_decode_paged(
                cfg, sp, layers, tok, h_in, entry, cg, pos, kp, vp, tb,
                k_scales=ks, v_scales=vs, interpret=interpret)
            new = jax.tree.map(lambda full, n: full.at[idx].set(n),
                               caches, nc)
            return h, logits, new, kp, vp, ks, vs

        self._decode = jax.jit(decode_fn,
                               donate_argnums=() if on_cpu else (7, 8, 9, 10))
        # per-slot {kept_tokens: {page_id: (k, v, ks, vs)}} verify snapshots
        self._spec_snaps: Dict[int, Dict[int, dict]] = {}

    # -- pool ------------------------------------------------------------
    def ensure(self, slot: int, tokens: int) -> bool:
        return self.pool.ensure(slot, tokens)

    def release(self, slot: int) -> None:
        self._spec_snaps.pop(slot, None)
        self.pool.release(slot)
        self.free_slot(slot)

    def kv_tokens_used(self) -> int:
        return self.pool.tokens_used

    def kv_tokens_capacity(self) -> int:
        return self.pool.tokens_capacity

    # -- prefill ---------------------------------------------------------
    def prefill_chunk(self, slot: int, x, entry: int, start: int):
        """One prompt chunk through the slice (all-paged stacks).  x: (C,)
        tokens or (1, C, d) activations.  Returns chunk activations
        (1, C, d), or last-token logits (V,) at the final stage."""
        if entry == 0:
            xin = jnp.asarray(np.asarray(x, np.int32))[None, :]
        else:
            xin = jnp.asarray(x)
        C = xin.shape[1]
        tb = jnp.asarray(self.pool.table[:, slot:slot + 1])
        n_act = _active_blocks_bucket(start + C, self.pool.page,
                                      self.pool.blocks_per_seq)
        pool = self.pool
        out, pool.k, pool.v, pool.k_scales, pool.v_scales = \
            self._prefill_chunk(
                self.sparams, xin, entry, jnp.asarray([start], jnp.int32),
                pool.k, pool.v, pool.k_scales, pool.v_scales, tb,
                n_act=n_act)
        return np.asarray(out)[0] if self.is_last else np.asarray(out)

    def prefill_stage(self, slot: int, x, entry: int):
        """Single-shot prompt pass (hybrid stacks): dense prefill of the
        slice, then the paged blocks' K/V is scattered into this slot's
        pages and the dense fallback caches spliced into the slot."""
        if self._chunked:
            raise RuntimeError("all-paged slice: drive prefill_chunk instead")
        if entry == 0:
            S = len(x)
            xin = jnp.asarray(np.asarray(x, np.int32))[None, :]
        else:
            S = x.shape[1]
            xin = jnp.asarray(x)
        out, caches1 = self._prefill_one(self.sparams, xin, entry)
        pool = self.pool
        caches1, pool.k, pool.v, pool.k_scales, pool.v_scales = \
            stage_absorb_dense_prefill(
                self.cfg, self.layers, caches1, pool.k, pool.v,
                pool.table, slot, S, pool.page,
                k_scales=pool.k_scales, v_scales=pool.v_scales)
        self.caches = jax.tree.map(
            lambda full, one: _splice(full, one, slot), self.caches, caches1)
        return np.asarray(out)[0] if self.is_last else np.asarray(out)

    # -- KV handoff (disaggregated prefill -> decode replicas) -----------
    def export_kv(self, slot: int, tokens: int, layers: List[int]):
        """Snapshot this slot's KV for the given *global* layer indices:
        paged blocks ship their live pages (int8 pages + per-page scales
        travel as-is, no requantization), hybrid dense blocks ship their
        cache subtree."""
        want = set(layers)
        nb = -(-tokens // self.pool.page)
        out = {}
        li = 0
        for (l, b), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            paged = is_paged_block(self.cfg, b)
            if l in want:
                if paged:
                    pids = self.pool.table[li, slot, :nb]
                    p = {"k": np.asarray(self.pool.k[pids]),
                         "v": np.asarray(self.pool.v[pids])}
                    if self.pool.quantized:
                        p["ks"] = np.asarray(self.pool.k_scales[pids])
                        p["vs"] = np.asarray(self.pool.v_scales[pids])
                    out[l] = p
                else:
                    out[l] = jax.tree.map(lambda a: np.asarray(a[slot]), c)
            if paged:
                li += 1
        return out

    def import_kv(self, slot: int, tokens: int, payload) -> None:
        """Scatter a shipped KV snapshot into this slot.  The runtime
        reserves the slot's blocks at admission; ``ensure`` here is a
        defensive no-op growth in the common case."""
        if not self.pool.ensure(slot, tokens):
            raise RuntimeError(
                f"import_kv: pool cannot hold {tokens} tokens in slot "
                f"{slot}")
        nb = -(-tokens // self.pool.page)
        pool = self.pool
        new = []
        li = 0
        for (l, b), c in zip(stage_blocks(self.cfg, self.layers),
                             self.caches):
            paged = is_paged_block(self.cfg, b)
            p = payload.get(l)
            if p is None:
                new.append(c)
            elif paged:
                pids = jnp.asarray(pool.table[li, slot, :nb])
                pool.k = pool.k.at[pids].set(
                    jnp.asarray(p["k"]).astype(pool.k.dtype))
                pool.v = pool.v.at[pids].set(
                    jnp.asarray(p["v"]).astype(pool.v.dtype))
                if pool.quantized:
                    pool.k_scales = pool.k_scales.at[pids].set(
                        jnp.asarray(p["ks"]))
                    pool.v_scales = pool.v_scales.at[pids].set(
                        jnp.asarray(p["vs"]))
                new.append(c)
            else:
                new.append(jax.tree.map(
                    lambda full, a: full.at[slot].set(jnp.asarray(a)),
                    c, p))
            if paged:
                li += 1
        self.caches = new

    # -- decode ----------------------------------------------------------
    def _decode_step(self, items: List[DecodeItem]):
        idx, tok, pos, entry, h_in = self._assemble(items)
        tables = jnp.asarray(self.pool.table)
        pool = self.pool
        (h, logits, self.caches, pool.k, pool.v,
         pool.k_scales, pool.v_scales) = self._decode(
            self.sparams, self.caches, tok, h_in, entry, pos, idx,
            pool.k, pool.v, pool.k_scales, pool.v_scales, tables)
        return (np.asarray(h),
                np.asarray(logits) if logits is not None else None)

    # -- speculative rollback --------------------------------------------
    def _spec_begin(self, it: DecodeItem) -> None:
        if self.pool.quantized:
            self._spec_snaps[it.slot] = {}

    def _snap_substep(self, it: DecodeItem, s: int) -> None:
        """After verify sub-step ``s`` wrote row ``it.pos + s``, snapshot
        each paged layer's frontier page (bytes + scales), keyed by the
        token count a rollback to this sub-step would keep.

        Needed because ``quantized_append`` requantizes the whole touched
        page: a later — ultimately rejected — sub-step landing in the same
        page can raise its absmax scale and perturb the kept rows' bytes.
        Truncation alone cannot undo that; restoring this snapshot can."""
        if not self.pool.quantized:
            return           # row-granular writes: truncation is byte-exact
        pool = self.pool
        pos = it.pos + s
        snaps = {}
        for li in range(pool.num_layers):
            pid = int(pool.table[li, it.slot, pos // pool.page])
            snaps[pid] = (np.asarray(pool.k[pid]), np.asarray(pool.v[pid]),
                          np.asarray(pool.k_scales[pid]),
                          np.asarray(pool.v_scales[pid]))
        self._spec_snaps.setdefault(it.slot, {})[pos + 1] = snaps

    def rollback(self, slot: int, tokens: int) -> None:
        """Truncate ``slot``'s KV to ``tokens`` rows after a partially
        rejected verify pass.  int8 pools additionally restore the kept
        frontier pages from the matching sub-step snapshot, leaving the
        pool byte-identical to a history that only ever decoded the
        accepted prefix; freed blocks self-clean on reuse because
        ``quantized_append`` zeroes rows past the append window before
        computing scales."""
        pool = self.pool
        snaps = self._spec_snaps.pop(slot, None)
        if pool.quantized and snaps:
            snap = snaps.get(tokens)
            if snap is not None:
                for pid, (k, v, ks, vs) in snap.items():
                    pool.k = pool.k.at[pid].set(jnp.asarray(k))
                    pool.v = pool.v.at[pid].set(jnp.asarray(v))
                    pool.k_scales = pool.k_scales.at[pid].set(
                        jnp.asarray(ks))
                    pool.v_scales = pool.v_scales.at[pid].set(
                        jnp.asarray(vs))
        pool.truncate(slot, tokens)


def make_stage_engine(cfg: ModelConfig, params, layers: LayerRange,
                      engine_cfg: EngineConfig, *, paged: bool = True,
                      **kw) -> _StageEngineBase:
    if paged:
        return PagedStageEngine(cfg, params, layers, engine_cfg, **kw)
    kw.pop("num_pages", None)
    kw.pop("page_size", None)
    kw.pop("kv_dtype", None)
    kw.pop("interpret", None)
    return StageEngine(cfg, params, layers, engine_cfg, **kw)
