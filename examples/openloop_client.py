"""Open-loop client for the online front door (launch/serve.py --serve).

Fires requests at the server on a wall-clock arrival process — Poisson at
``--rate`` (the same generator the simulator's traces use, so simulated
and served arrival patterns agree) or replaying a synthetic
Azure-Conversation-style trace (``--trace``) — WITHOUT waiting for earlier
requests to finish: arrival times are fixed up front, which is what makes
the measurement open-loop (a slow server cannot throttle its own load).

Each request streams (SSE) and records client-side TTFT (first token
chunk), mean TPOT, and E2E latency; the run reports p50/p95/p99 of each
plus SLO attainment against ``--slo-ttft-ms`` / ``--slo-tpot-ms``, and
exits non-zero on any transport error, non-200 response, or (with
``--check-ordered``) out-of-order SSE chunks.

Stdlib-only on purpose (urllib + threads): it must run anywhere the repo
runs, including the CI smoke job.

  PYTHONPATH=src python examples/openloop_client.py \
      --url http://127.0.0.1:8000 --rate 4 --requests 16 --stream \
      --slo-ttft-ms 2000 --slo-tpot-ms 1000 --check-ordered
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import threading
import time
import urllib.error
import urllib.request

sys.path.insert(0, "src")

from repro.sim.traces import arrival_times, make_trace  # noqa: E402


def percentile(xs, q):
    if not xs:
        return float("nan")
    ys = sorted(xs)
    i = (len(ys) - 1) * q / 100.0
    lo, hi = int(i), min(int(i) + 1, len(ys) - 1)
    return ys[lo] + (ys[hi] - ys[lo]) * (i - lo)


def wait_ready(url: str, timeout_s: float) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        try:
            with urllib.request.urlopen(url + "/healthz", timeout=5) as r:
                if r.status == 200:
                    return
        except (urllib.error.URLError, OSError):
            pass
        time.sleep(0.25)
    raise SystemExit(f"server at {url} not ready within {timeout_s:.0f}s")


def run_one(url: str, i: int, prompt, max_tokens: int, stream: bool,
            temperature: float, timeout_s: float, check_ordered: bool,
            out: dict) -> None:
    body = json.dumps({"prompt": prompt, "max_tokens": max_tokens,
                       "stream": stream,
                       "temperature": temperature}).encode("utf-8")
    req = urllib.request.Request(
        url + "/v1/completions", data=body,
        headers={"Content-Type": "application/json"})
    t0 = time.monotonic()
    rec = {"id": i, "error": None, "tokens": 0, "ttft_s": None,
           "tpot_s": None, "e2e_s": None, "finish": None}
    out[i] = rec
    try:
        with urllib.request.urlopen(req, timeout=timeout_s) as resp:
            if not stream:
                obj = json.load(resp)
                rec["tokens"] = len(obj["choices"][0].get("token_ids", []))
                rec["finish"] = obj["choices"][0]["finish_reason"]
                rec["e2e_s"] = time.monotonic() - t0
                return
            t_first = t_last = None
            n = 0
            for raw in resp:
                line = raw.strip()
                if not line.startswith(b"data: "):
                    continue
                data = line[len(b"data: "):]
                if data == b"[DONE]":
                    break
                choice = json.loads(data)["choices"][0]
                if choice.get("token_id") is not None:
                    t_last = time.monotonic()
                    if t_first is None:
                        t_first = t_last
                    if check_ordered and choice.get("output_index") != n:
                        rec["error"] = (f"out-of-order chunk: expected "
                                        f"output_index {n}, got "
                                        f"{choice.get('output_index')}")
                        return
                    n += 1
                if choice.get("finish_reason"):
                    rec["finish"] = choice["finish_reason"]
            t_end = time.monotonic()
            rec["tokens"] = n
            rec["e2e_s"] = t_end - t0
            if t_first is not None:
                rec["ttft_s"] = t_first - t0
                if n > 1:
                    rec["tpot_s"] = (t_last - t_first) / (n - 1)
            if rec["finish"] is None:
                rec["error"] = "stream ended without finish_reason"
    except urllib.error.HTTPError as e:
        rec["error"] = f"HTTP {e.code}: {e.read()[:200].decode(errors='replace')}"
    except (urllib.error.URLError, OSError) as e:
        rec["error"] = f"transport: {e}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--url", default="http://127.0.0.1:8000")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--trace", action="store_true",
                    help="arrivals (and output lengths) from the synthetic "
                         "Azure-Conversation trace instead of plain Poisson")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=256,
                    help="prompt token ids drawn uniformly from [0, vocab)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true", default=True)
    ap.add_argument("--no-stream", dest="stream", action="store_false")
    ap.add_argument("--timeout-s", type=float, default=120.0,
                    help="per-request HTTP timeout")
    ap.add_argument("--wait-ready-s", type=float, default=0.0,
                    help="poll /healthz up to this long before starting")
    ap.add_argument("--check-ordered", action="store_true",
                    help="fail on out-of-order SSE output_index")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0)
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0)
    args = ap.parse_args()

    if args.wait_ready_s > 0:
        wait_ready(args.url, args.wait_ready_s)

    rng = random.Random(args.seed)
    n = args.requests
    if args.trace:
        tr = make_trace(n, args.rate, seed=args.seed)
        arrivals = [t.arrival_s for t in tr]
        lengths = [min(t.output_tokens, args.max_tokens) for t in tr]
    else:
        arrivals = arrival_times(n, args.rate, seed=args.seed)
        lengths = [args.max_tokens] * n
    prompts = [[rng.randrange(args.vocab) for _ in range(args.prompt_len)]
               for _ in range(n)]

    out: dict = {}
    threads = []
    t_start = time.monotonic()
    for i in range(n):
        delay = t_start + arrivals[i] - time.monotonic()
        if delay > 0:
            time.sleep(delay)          # open loop: fixed arrival schedule
        th = threading.Thread(target=run_one,
                              args=(args.url, i, prompts[i], lengths[i],
                                    args.stream, args.temperature,
                                    args.timeout_s, args.check_ordered, out),
                              daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=args.timeout_s + 30)
    wall = time.monotonic() - t_start

    recs = [out[i] for i in sorted(out)]
    errors = [r for r in recs if r["error"]]
    for r in errors:
        print(f"req {r['id']}: {r['error']}", file=sys.stderr)
    done = [r for r in recs if not r["error"]]
    ttfts = [r["ttft_s"] for r in done if r["ttft_s"] is not None]
    tpots = [r["tpot_s"] for r in done if r["tpot_s"] is not None]
    e2es = [r["e2e_s"] for r in done if r["e2e_s"] is not None]
    ok = 0
    for r in done:
        good = True
        if args.slo_ttft_ms > 0 and r["ttft_s"] is not None:
            good = good and r["ttft_s"] * 1e3 <= args.slo_ttft_ms
        if args.slo_tpot_ms > 0 and r["tpot_s"] is not None:
            good = good and r["tpot_s"] * 1e3 <= args.slo_tpot_ms
        ok += bool(good)
    summary = {
        "requests": n, "completed": len(done), "errors": len(errors),
        "wall_s": round(wall, 3),
        "achieved_rate_per_s": round(n / wall, 3) if wall > 0 else None,
        "tokens": sum(r["tokens"] for r in done),
        "ttft_s": {f"p{q}": round(percentile(ttfts, q), 4)
                   for q in (50, 95, 99)},
        "tpot_s": {f"p{q}": round(percentile(tpots, q), 4)
                   for q in (50, 95, 99)},
        "e2e_s": {f"p{q}": round(percentile(e2es, q), 4)
                  for q in (50, 95, 99)},
        "slo_attainment": round(ok / len(done), 4) if done else None,
    }
    print(json.dumps(summary))
    if errors or len(done) < n:
        raise SystemExit(1)
    if any(t is not None and t < 0 for t in ttfts + tpots):
        raise SystemExit("negative latency measured")


if __name__ == "__main__":
    main()
