"""Fault-tolerance demo: kill a node mid-serving, watch Helix replan.

Part 1 — real execution: a 3-node cluster serves a smoke model through the
ClusterRuntime (every node a stage engine over its MILP slice).  Mid-decode
we kill a node: its engine is dropped, in-flight requests crossing it release
their KV on the survivors and requeue; the coordinator re-solves placement on
the survivors, the runtime adopts the new plan (rebuilding engines whose
slice moved, swapping IWRR weights), and the requeued requests re-prefill
(prompt + already-generated tokens) on fresh pipelines — every request still
finishes with its full output.

Part 2 — at scale (simulated): 24 nodes serving LLaMA-70B offline; at t=60s
the strongest A100 dies.  Replanning (LNS warm-started from the surviving
assignment) vs no replanning.

Run:  PYTHONPATH=src python examples/failover.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.core import (LLAMA_70B, MILPOptions, ModelProfile,
                        make_serving_cluster, make_single_cluster, plan,
                        replan_after_failure)
from repro.sim import Simulator, make_offline_trace
from repro.models import init
from repro.serving import ClusterRuntime, EngineConfig, Request


def run_real() -> None:
    cfg = get_smoke_config("smollm_360m")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    cluster = make_serving_cluster(profile, devs=("A100", "L4", "T4"),
                                   force_stages=2)
    p = plan(cluster, profile, MILPOptions(time_limit_s=10.0, lns_rounds=0,
                                           fgls_rounds=20))
    for node, rng_ in sorted(p.placement.assignment.items()):
        print(f"  {node}: layers [{rng_.start}, {rng_.end})")

    params = init(cfg, jax.random.key(0))
    rt = ClusterRuntime(cfg, params, p,
                        EngineConfig(max_batch=4, max_len=64, prompt_len=16))
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(10,)),
                    max_new_tokens=10) for i in range(4)]
    for r in reqs:
        rt.submit(r)
    for _ in range(10):                      # get requests mid-decode
        rt.step()
    print("  mid-run tokens:", [len(r.output) for r in reqs])

    victim = max(rt.engines, key=lambda n: cluster.nodes[n].flops)
    print(f"  !! killing {victim} mid-decode")
    rt.fail_node(victim)
    new = replan_after_failure(p, victim,
                               MILPOptions(time_limit_s=8.0, lns_rounds=0,
                                           fgls_rounds=20))
    print(f"  replanned on survivors: "
          + ", ".join(f"{n}[{r.start},{r.end})"
                      for n, r in sorted(new.placement.assignment.items())))
    rt.apply_plan(new)
    rt.run_until_done()
    assert all(r.done for r in reqs)
    assert all(v == 0 for v in rt.pool_pages_used().values())
    print("  all requests completed after failover; outputs intact "
          f"(re-prefills: {[r.preemptions for r in reqs]})")


def run_sim(with_replan: bool) -> None:
    cluster = make_single_cluster()
    p = plan(cluster, LLAMA_70B, MILPOptions(time_limit_s=15.0, lns_rounds=1,
                                             fgls_rounds=40))
    sched = p.make_scheduler()
    state = {"plan": p}

    def replan(dead):
        print(f"  !! node {dead} failed -> replanning on "
              f"{len(state['plan'].cluster.nodes) - 1} survivors")
        new = replan_after_failure(
            state["plan"], dead,
            MILPOptions(time_limit_s=8.0, lns_rounds=0, fgls_rounds=30))
        state["plan"] = new
        print(f"  new max-flow bound: {new.throughput:.0f} tok/s")
        return new.make_scheduler(), new.placement

    sim = Simulator(cluster, LLAMA_70B, p.placement, sched, warmup_s=10.0,
                    horizon_s=240.0, decode_chunk=4,
                    replan_fn=replan if with_replan else None)
    victim = max(p.placement.assignment,
                 key=lambda n: cluster.nodes[n].flops)
    sim.fail_node(60.0, victim)
    m = sim.run(make_offline_trace(400, seed=7))
    mode = "with replanning" if with_replan else "NO replanning"
    print(f"[{mode}] decode throughput {m.decode_throughput:.0f} tok/s, "
          f"completed {m.completed_requests}, restarts {m.restarts}")


def main() -> None:
    print("real execution (ClusterRuntime failover):")
    run_real()
    print("\nsimulated at scale — baseline (failure + elastic replanning):")
    run_sim(True)
    print("\nablation (failure, no replanning):")
    run_sim(False)


if __name__ == "__main__":
    main()
