"""Fault-tolerance demo: kill a node mid-serving, watch Helix replan.

Simulated 24-node cluster serving LLaMA-70B offline; at t=60s the strongest
A100 dies.  The coordinator re-solves placement on the survivors (LNS warm-
started from the surviving assignment), swaps IWRR weights, and affected
requests restart.  Compares against a run with no replanning.

Run:  PYTHONPATH=src python examples/failover.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (LLAMA_70B, MILPOptions, make_single_cluster, plan,
                        replan_after_failure)
from repro.sim import Simulator, make_offline_trace


def run(with_replan: bool) -> None:
    cluster = make_single_cluster()
    p = plan(cluster, LLAMA_70B, MILPOptions(time_limit_s=15.0, lns_rounds=1,
                                             fgls_rounds=40))
    sched = p.make_scheduler()
    state = {"plan": p}

    def replan(dead):
        print(f"  !! node {dead} failed -> replanning on "
              f"{len(state['plan'].cluster.nodes) - 1} survivors")
        new = replan_after_failure(
            state["plan"], dead,
            MILPOptions(time_limit_s=8.0, lns_rounds=0, fgls_rounds=30))
        state["plan"] = new
        print(f"  new max-flow bound: {new.throughput:.0f} tok/s")
        return new.make_scheduler(), new.placement

    sim = Simulator(cluster, LLAMA_70B, p.placement, sched, warmup_s=10.0,
                    horizon_s=240.0, decode_chunk=4,
                    replan_fn=replan if with_replan else None)
    victim = max(p.placement.assignment,
                 key=lambda n: cluster.nodes[n].flops)
    sim.fail_node(60.0, victim)
    m = sim.run(make_offline_trace(400, seed=7))
    mode = "with replanning" if with_replan else "NO replanning"
    print(f"[{mode}] decode throughput {m.decode_throughput:.0f} tok/s, "
          f"completed {m.completed_requests}, restarts {m.restarts}")


def main() -> None:
    print("baseline (failure + elastic replanning):")
    run(True)
    print("\nablation (failure, no replanning):")
    run(False)


if __name__ == "__main__":
    main()
