"""End-to-end driver: serve a small model with batched requests through the
full Helix pipeline — MILP placement, per-request IWRR pipelines, and the
ClusterRuntime executing each stage's layer slice on its own engine.

This is the paper's system in miniature: the cluster-level scheduler decides
*where* each request's layers run; each node runs a stage engine holding only
its assigned contiguous layers (dense caches or a VRAM-sized page pool), and
activations hop between nodes through the in-process Transport.

Run:  PYTHONPATH=src python examples/serve_cluster.py [--requests 8]
      ... --force-stages 2 --check     # force a real multi-stage pipeline
                                       # and verify token-for-token against
                                       # a single full-model engine
      ... --transport socket           # one StageWorker *process* per node
                                       # behind the SocketTransport instead
                                       # of the in-process virtual clock
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.core import (LayerRange, MILPOptions, ModelProfile,
                        disaggregated_placement, make_serving_cluster, plan)
from repro.models import init
from repro.serving import (ClusterRuntime, Engine, EngineConfig,
                           InProcessTransport, Request)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--dense", action="store_true",
                    help="dense per-slot stage engines instead of paged KV")
    ap.add_argument("--force-stages", type=int, default=0,
                    help="derate VRAM so placements need >= N stages")
    ap.add_argument("--delay-ms", type=float, default=0.0,
                    help="modelled inter-stage transport delay")
    ap.add_argument("--max-inflight", type=int, default=1,
                    help="per-request in-flight decode window: >= 2 lets "
                         "the final stage launch token t+1 while token t "
                         "travels back to the coordinator")
    ap.add_argument("--transport", choices=["inproc", "socket"],
                    default="inproc",
                    help="inproc: every stage engine in this process on a "
                         "virtual clock; socket: one StageWorker process "
                         "per node behind the SocketTransport (real bytes, "
                         "real wall clock)")
    ap.add_argument("--kv-dtype", choices=["param", "int8"], default="param",
                    help="KV page storage on paged stage engines; int8 "
                         "quantizes pages for ~2x pool capacity")
    ap.add_argument("--direct-links", action="store_true",
                    help="route stage outputs worker-to-worker (socket: "
                         "real peer TCP links; inproc: modelled) instead "
                         "of bouncing every frame through the coordinator")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split the cluster into a prefill replica (first "
                         "node, full model) and a decode replica (remaining "
                         "nodes, even contiguous split); prompt KV ships "
                         "prefill -> decode over the transport")
    ap.add_argument("--draft", default="",
                    help="arch name of a coordinator-side draft model: "
                         "greedy speculative decoding, --spec-tokens drafts "
                         "verified per pipeline round-trip")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="with --draft: draft tokens per verify pass (gamma)")
    ap.add_argument("--check", action="store_true",
                    help="verify against one full engine: token-for-token "
                         "for param-dtype KV, tolerance (majority token "
                         "agreement + matching first token) for int8")
    args = ap.parse_args()

    cfg = get_smoke_config("smollm_360m")
    if args.check:
        # float32 so paged (Pallas online-softmax) and dense logits agree
        # to argmax precision
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    cluster = make_serving_cluster(profile, force_stages=args.force_stages)

    if args.disaggregate:
        names = sorted(cluster.nodes)
        if len(names) < 2:
            raise SystemExit("--disaggregate needs >= 2 nodes")
        dec = names[1:]
        L = cfg.num_layers
        bounds = [round(i * L / len(dec)) for i in range(len(dec) + 1)]
        placement = disaggregated_placement(
            {names[0]: LayerRange(0, L)},
            {n: LayerRange(bounds[i], bounds[i + 1])
             for i, n in enumerate(dec)}, L)
        print("disaggregated placement (no MILP) ...")
        p = plan(cluster, profile, placement=placement)
    else:
        print("planning placement ...")
        p = plan(cluster, profile, MILPOptions(time_limit_s=10.0,
                                               lns_rounds=0, fgls_rounds=20))
    roles = p.placement.meta.get("roles", {})
    for node, rng in sorted(p.placement.assignment.items()):
        role = f" role={roles[node]}" if roles else ""
        print(f"  {node}: layers [{rng.start}, {rng.end}) "
              f"({cluster.nodes[node].device.name}){role}")

    params = init(cfg, jax.random.key(0))
    ec = EngineConfig(max_batch=4, max_len=64, prompt_len=16)
    kv_dtype = args.kv_dtype if args.kv_dtype != "param" else None
    spec_kw = {}
    if args.draft:
        dcfg = get_smoke_config(args.draft)
        if args.check:
            dcfg = dataclasses.replace(dcfg, param_dtype="float32",
                                       compute_dtype="float32")
        print(f"draft: {dcfg.name} ({dcfg.num_layers}L d={dcfg.d_model}), "
              f"spec_tokens={args.spec_tokens}")
        spec_kw = dict(draft_cfg=dcfg,
                       draft_params=init(dcfg, jax.random.key(0)),
                       spec_tokens=args.spec_tokens)
    if args.transport == "socket":
        rt = ClusterRuntime.spawn_workers(cfg, params, p, ec,
                                          paged=not args.dense,
                                          kv_dtype=kv_dtype,
                                          max_inflight=args.max_inflight,
                                          stall_timeout_s=120.0,
                                          direct_links=args.direct_links,
                                          **spec_kw)
    else:
        transport = InProcessTransport(default_delay_s=args.delay_ms * 1e-3,
                                       direct_links=args.direct_links)
        rt = ClusterRuntime(cfg, params, p, ec, paged=not args.dense,
                            transport=transport, kv_dtype=kv_dtype,
                            max_inflight=args.max_inflight, **spec_kw)
    if not args.dense:
        for node, eng in sorted(rt.engines.items()):
            pages = eng.pool.num_pages if hasattr(eng, "pool") \
                else eng.pool_num_pages()          # remote: over RPC
            print(f"  {node}: pool {pages} pages"
                  + (" (worker process)" if args.transport == "socket"
                     else ""))

    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(10,)),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        rt.submit(r)
    rt.run_until_done()
    dt = time.time() - t0

    stage_counts = []
    for r in reqs:
        pipe = rt.served[r.request_id]
        stage_counts.append(len(pipe.stages))
        print(f"req{r.request_id} -> "
              + " -> ".join(f"{s.node}[{s.layers.start},{s.layers.end})"
                            for s in pipe.stages))

    done = sum(r.done for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    print(f"\nserved {done}/{len(reqs)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s on CPU)")
    if args.delay_ms > 0:
        print(f"mean decode latency (virtual clock, in-flight window "
              f"{args.max_inflight}): {rt.mean_decode_latency() * 1e3:.2f}ms"
              f"/token")
    describe = getattr(rt.transport, "describe", None)
    if callable(describe):
        print(f"transport: {describe()}")
    if args.draft:
        print(f"  {rt._spec_note()}")
        assert rt.spec_rounds > 0, "draft attached but no verify rounds ran"
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {r.output}")
    assert done == len(reqs), "not all requests completed"
    if not args.dense:
        assert all(v == 0 for v in rt.pool_pages_used().values()), \
            "pages leaked"
    if args.force_stages > 1:
        assert max(stage_counts) >= args.force_stages, \
            f"expected >= {args.force_stages}-stage pipelines, " \
            f"got {stage_counts} — cross-node serving regressed"

    if args.check:
        ref = Engine(cfg, params, ec)
        ref_reqs = [Request(r.request_id, r.prompt,
                            max_new_tokens=r.max_new_tokens) for r in reqs]
        for r in ref_reqs:
            ref.submit(r)
        ref.run_until_done(2000)
        if kv_dtype == "int8":
            # int8 KV is lossy, so greedy trajectories may diverge once a
            # near-tie flips (and this smoke model's random weights make
            # every step a near-tie) — check within tolerance: most
            # requests' first decoded token must survive the quantization
            # round, and a majority of all tokens must agree overall
            hits = total = first = 0
            for r, rr in zip(reqs, ref_reqs):
                first += r.output[0] == rr.output[0]
                hits += sum(a == b for a, b in zip(r.output, rr.output))
                total += len(rr.output)
            agree = hits / max(total, 1)
            assert first * 2 >= len(reqs), \
                f"int8 first-token agreement {first}/{len(reqs)} < half"
            assert agree >= 0.5, f"int8 token agreement {agree:.2f} < 0.5"
            print(f"check: int8 within tolerance of the full-model engine "
                  f"({first}/{len(reqs)} first tokens exact, "
                  f"{agree:.0%} of all tokens agree)")
        else:
            for r, rr in zip(reqs, ref_reqs):
                assert r.output == rr.output, \
                    (r.request_id, r.output, rr.output)
            print("check: token-for-token identical to a single full-model "
                  "engine")

    rt.shutdown()                      # reap worker processes (socket runs)


if __name__ == "__main__":
    main()
