"""End-to-end driver: serve a small model with batched requests through the
full Helix pipeline — MILP placement, per-request IWRR pipelines, and the
real JAX engine executing each stage's layer slice.

This is the paper's system in miniature: the cluster-level scheduler decides
*where* each request's layers run; each "node" runs a JAX Engine over its
assigned contiguous layers (here all nodes share one process/CPU).

Run:  PYTHONPATH=src python examples/serve_cluster.py [--requests 8]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.core import (COORDINATOR, MILPOptions, ModelProfile, plan)
from repro.core.cluster import DEVICE_PROFILES, ClusterSpec, NodeSpec
from repro.core.cluster import _full_mesh_links
from repro.models import init
from repro.serving import (Engine, EngineConfig, PagedEngine, Request,
                           full_rectangle_pages, pages_for_vram)


def make_cluster(devs=("A100", "L4", "T4")):
    nodes, regions = {}, {COORDINATOR: "r0"}
    for i, d in enumerate(devs):
        name = f"n{i}"
        nodes[name] = NodeSpec(name, DEVICE_PROFILES[d], region="r0")
        regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions, 10e9 / 8, 1e-3,
                             10e9 / 8, 1e-3)
    return ClusterSpec(nodes=nodes, links=links)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--dense", action="store_true",
                    help="use the dense per-slot engine instead of paged KV")
    args = ap.parse_args()

    cfg = get_smoke_config("smollm_360m")
    cluster = make_cluster()
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)

    print("planning placement ...")
    p = plan(cluster, profile, MILPOptions(time_limit_s=10.0, lns_rounds=0,
                                           fgls_rounds=20))
    for node, rng in sorted(p.placement.assignment.items()):
        print(f"  {node}: layers [{rng.start}, {rng.end})")

    sched = p.make_scheduler()
    params = init(cfg, jax.random.key(0))
    # one Engine per node — in production each runs on its own slice; here
    # they share the host and serve the full model for requests routed to
    # them as first-stage (single-stage pipelines for this tiny model).
    ec = EngineConfig(max_batch=4, max_len=64, prompt_len=16)
    if args.dense:
        engines = {node: Engine(cfg, params, ec)
                   for node in p.placement.assignment}
    else:
        # paged KV: each node's pool is sized from *its* VRAM (capped at the
        # full rectangle for this smoke model) — the memory heterogeneity
        # Helix's placement exploits
        page = 16
        rect = full_rectangle_pages(cfg, max_batch=ec.max_batch,
                                    max_len=ec.max_len, page_size=page)
        engines = {}
        for node, rng_ in sorted(p.placement.assignment.items()):
            vram_pages = pages_for_vram(
                cfg, cluster.nodes[node].vram_bytes, page_size=page,
                layers_on_node=rng_.num_layers, max_pages=rect)
            print(f"  {node}: pool {vram_pages} pages "
                  f"({cluster.nodes[node].device.name})")
            engines[node] = PagedEngine(cfg, params, ec,
                                        num_pages=vram_pages, page_size=page)

    rng = np.random.RandomState(0)
    reqs = []
    t0 = time.time()
    for i in range(args.requests):
        pipe = sched.schedule(prompt_tokens=10)
        first = pipe.stages[0].node
        r = Request(i, rng.randint(0, cfg.vocab_size, size=(10,)),
                    max_new_tokens=args.new_tokens)
        engines[first].submit(r)
        reqs.append((r, pipe))
        print(f"req{i} -> pipeline "
              + " -> ".join(s.node for s in pipe.stages))

    for node, eng in engines.items():
        eng.run_until_done(max_iters=500)
    dt = time.time() - t0

    done = sum(r.done for r, _ in reqs)
    toks = sum(len(r.output) for r, _ in reqs)
    print(f"\nserved {done}/{len(reqs)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s on CPU)")
    for r, _ in reqs[:3]:
        print(f"  req{r.request_id}: {r.output}")


if __name__ == "__main__":
    main()
