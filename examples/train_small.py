"""End-to-end training driver: train a small LM for a few hundred steps with
checkpoint/restart, using the full substrate (model zoo config, AdamW,
remat, async checkpointing, resumable data pipeline).

Default config is CPU-sized; ``--preset 100m`` selects a ~100M-parameter
model (the assignment's reference size — expect minutes/step on CPU, real
use is TPU via repro.launch.train).

Run:  PYTHONPATH=src python examples/train_small.py --steps 60
      PYTHONPATH=src python examples/train_small.py --steps 60 --resume
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import init, loss_fn
from repro.training import (AsyncCheckpointer, DataConfig, OptimizerConfig,
                            TrainConfig, init_train_state, latest_step,
                            make_batch, make_train_step, restore)


def make_config(preset: str) -> ModelConfig:
    if preset == "100m":
        return ModelConfig(
            name="lm-100m", family="dense", d_model=768, num_heads=12,
            num_kv_heads=12, d_ff=2048, vocab_size=32768,
            pattern=(BlockSpec(kind="attn", attn="full"),), repeats=12,
            norm="rmsnorm", tie_embeddings=True)
    return ModelConfig(
        name="lm-tiny", family="dense", d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=384, vocab_size=2048,
        pattern=(BlockSpec(kind="attn", attn="full"),), repeats=4,
        norm="rmsnorm", tie_embeddings=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = make_config(args.preset)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")

    tc = TrainConfig(optimizer=OptimizerConfig(
        lr=3e-3, warmup_steps=20, total_steps=args.steps), remat="none")
    dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=args.batch,
                    seq_len=args.seq, seed=0)

    params = init(cfg, jax.random.key(0))
    opt_state = init_train_state(cfg, tc, params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        state, step, meta = restore(args.ckpt_dir, None,
                                    {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = meta["data_step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, tc))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)
    t0 = time.time()
    for s in range(start, args.steps):
        batch = make_batch(dc, s)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if s % 10 == 0 or s == args.steps - 1:
            tok_s = (s - start + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {s:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tok_s:.0f} tok/s")
        if (s + 1) % args.ckpt_every == 0:
            ckpt.save_async(s + 1, {"params": params, "opt": opt_state},
                            metadata={"data_step": s + 1})
    ckpt.wait()
    print("done.")


if __name__ == "__main__":
    main()
