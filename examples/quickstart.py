"""Quickstart: plan a heterogeneous cluster with Helix and inspect the plan.

Builds the paper's 24-node single cluster (4xA100 + 8xL4 + 12xT4), solves
model placement for LLaMA-70B via max-flow MILP (+FGLS refinement), prints
the placement, the max-flow edge usage, and a few per-request pipelines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (COORDINATOR, LLAMA_70B, MILPOptions, compute_upper_bound,
                        make_single_cluster, plan)


def main() -> None:
    cluster = make_single_cluster()
    model = LLAMA_70B
    print(f"cluster: {len(cluster.nodes)} nodes; model: {model.name} "
          f"({model.num_layers} layers)")

    p = plan(cluster, model, MILPOptions(time_limit_s=20.0, lns_rounds=1,
                                         lns_time_limit_s=8.0,
                                         fgls_rounds=60))
    ub = compute_upper_bound(cluster, model)
    print(f"\nmax-flow throughput: {p.throughput:.0f} tokens/s "
          f"({100 * p.throughput / ub:.0f}% of the compute-sum bound)")
    if p.milp is not None:
        print("optimizer path:")
        for h in p.milp.meta["history"]:
            print(f"  {h['phase']:24s} -> {h['throughput']:.0f} tok/s")

    print("\nplacement (node: layers [start, end)):")
    for node, rng in sorted(p.placement.assignment.items()):
        cap = p.graph.node_capacity[node]
        print(f"  {node:10s} [{rng.start:3d}, {rng.end:3d})  "
              f"capacity {cap:8.0f} tok/s")

    print("\nbusiest links in the max-flow solution:")
    for (src, dst), f in sorted(p.flows.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {src:12s} -> {dst:12s}  {f:8.0f} tok/s")

    sched = p.make_scheduler()
    print("\nper-request pipelines (IWRR over max-flow weights):")
    for i in range(5):
        pipe = sched.schedule(prompt_tokens=763)
        path = " -> ".join(f"{s.node}[{s.layers.start}:{s.layers.end}]"
                           for s in pipe.stages)
        print(f"  req{i}: {path}")


if __name__ == "__main__":
    main()
