"""Event-driven simulator tests: throughput sanity, latency, pipelined
decode overlap, fault injection."""
import pytest

from repro.core import MILPOptions, plan, replan_after_failure
from repro.sim import Simulator, make_offline_trace, make_trace
from repro.sim.traces import TraceRequest, azure_conversation_lengths
import random

from harness import make_cluster, small_model


def run_sim(devs=("A100", "A100"), layers=4, n_req=400, horizon=120.0,
            offline=True, warmup=0.5, **kw):
    cluster = make_cluster(devs)
    model = small_model(layers)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    sched = p.make_scheduler()
    trace = make_offline_trace(n_req, seed=1) if offline else \
        make_trace(n_req, arrival_rate_per_s=2.0, seed=1)
    sim = Simulator(cluster, model, p.placement, sched, warmup_s=warmup,
                    horizon_s=horizon, **kw)
    return p, sim, sim.run(trace)


def test_trace_statistics():
    rng = random.Random(0)
    ins, outs = zip(*(azure_conversation_lengths(rng) for _ in range(4000)))
    assert 600 < sum(ins) / len(ins) < 950     # paper: mean 763
    assert 170 < sum(outs) / len(outs) < 330   # paper: mean 232
    assert max(ins) <= 2048 and max(outs) <= 1024


def test_unroutable_requests_dropped_not_retried_forever():
    """Regression: _arrive used to retry a failed schedule() every 0.5 s
    forever; it must cap retries (like _restart) and count the drops."""
    from repro.core import MILPOptions, plan as _plan
    cluster = make_cluster(("A100", "A100"))
    model = small_model(4)
    p = _plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    sched = p.make_scheduler()
    sched.update_weights({})          # no routes: every schedule() fails
    sim = Simulator(cluster, model, p.placement, sched, warmup_s=0.0,
                    horizon_s=600.0)
    m = sim.run(make_offline_trace(5, seed=1))
    assert m.dropped_requests == 5
    assert m.completed_requests == 0


def test_simulator_produces_tokens():
    _, sim, m = run_sim()
    assert m.decoded_tokens > 0
    assert m.completed_requests > 0
    assert m.decode_throughput > 0


def test_throughput_bounded_by_capacity():
    """Sim throughput can never exceed the max-flow bound of the placement."""
    p, sim, m = run_sim(n_req=300, horizon=60.0)
    assert m.decode_throughput <= p.throughput * 1.10  # +10% discretization


def test_throughput_approaches_flow_under_load():
    """With saturating offline load, sim throughput should reach a decent
    fraction of the analytic max flow."""
    p, sim, m = run_sim(devs=("A100", "A100"), layers=4, n_req=2000,
                        horizon=120.0, decode_chunk=8)
    # max flow counts all tokens passing through (prompt + decode)
    assert m.processed_throughput >= 0.4 * p.throughput


def test_latency_recorded_online():
    _, sim, m = run_sim(offline=False, n_req=60, horizon=200.0)
    assert m.prompt_latency["mean"] > 0
    assert m.decode_latency["mean"] > 0
    # prompt latency should exceed decode per-token latency (more tokens)
    assert m.prompt_latency["mean"] > m.decode_latency["mean"]


def test_slow_link_hurts_throughput():
    """Cutting inter-node bandwidth 100x should not speed things up."""
    cluster_fast = make_cluster(("A100", "T4"))
    cluster_slow = make_cluster(("A100", "T4"), inter_bw=100e6 / 8)
    model = small_model(8)
    results = []
    for cluster in (cluster_fast, cluster_slow):
        p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
        sched = p.make_scheduler()
        sim = Simulator(cluster, model, p.placement, sched, warmup_s=5.0,
                        horizon_s=90.0)
        m = sim.run(make_offline_trace(400, seed=2))
        results.append(m.decode_throughput)
    assert results[0] >= results[1] * 0.95


def test_node_failure_with_replan_keeps_serving():
    cluster = make_cluster(("A100", "A100", "A100"))
    model = small_model(4)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    sched = p.make_scheduler()

    state = {"plan": p}

    def replan(dead):
        new = replan_after_failure(state["plan"], dead,
                                   MILPOptions(time_limit_s=8.0, lns_rounds=0))
        state["plan"] = new
        return new.make_scheduler(), new.placement

    sim = Simulator(cluster, model, p.placement, sched, warmup_s=5.0,
                    horizon_s=120.0, replan_fn=replan)
    sim.fail_node(30.0, "n0")
    m = sim.run(make_offline_trace(600, seed=3))
    assert m.decoded_tokens > 0
    # tokens decoded after the failure too: horizon extends past failure
    assert m.completed_requests > 0
    assert "n0" not in state["plan"].placement.assignment


def test_kv_accounting_drains_to_zero():
    """Decode growth past the reservation estimate must charge only the
    excess (not the full chunk) so completion frees exactly what was
    charged: node + scheduler KV must return to 0 after the trace drains."""
    cluster = make_cluster(("A100", "T4"))
    model = small_model(4)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    sched = p.make_scheduler()
    sim = Simulator(cluster, model, p.placement, sched, warmup_s=0.0,
                    horizon_s=600.0, kv_output_estimate=10, decode_chunk=4)
    # outputs cross the estimate at a non-chunk-aligned point (10 % 4 != 0)
    trace = [TraceRequest(i, 0.0, 32, 23) for i in range(30)]
    m = sim.run(trace)
    assert m.completed_requests == len(trace)
    for name, ns in sim.nodes.items():
        assert abs(ns.kv_used) < 1e-6, (name, ns.kv_used)
    if sched.kv is not None:
        for node, usage in sched.kv.usage.items():
            assert usage == 0.0, (node, usage)


def test_scheduler_reservations_drain_when_outputs_short():
    """Outputs *below* the reservation estimate: the scheduler must release
    exactly what it reserved (input + estimate), not input + decoded — the
    asymmetry left phantom usage that eventually high-water-masked nodes."""
    cluster = make_cluster(("A100", "T4"))
    model = small_model(4)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    sched = p.make_scheduler()
    sim = Simulator(cluster, model, p.placement, sched, warmup_s=0.0,
                    horizon_s=600.0, kv_output_estimate=64, decode_chunk=4)
    trace = [TraceRequest(i, 0.0, 32, 16) for i in range(40)]  # 16 < 64
    m = sim.run(trace)
    assert m.completed_requests == len(trace)
    assert sched.kv is not None
    for node, usage in sched.kv.usage.items():
        assert usage == 0.0, (node, usage)
    for name, ns in sim.nodes.items():
        assert abs(ns.kv_used) < 1e-6, (name, ns.kv_used)


def test_restart_releases_kv_reservations():
    """Node failure: restarted requests must release node/scheduler KV on
    the surviving nodes of the abandoned pipeline — kv_used drains to ~0
    once every request has completed (or been dropped)."""
    cluster = make_cluster(("A100", "A100", "A100"))
    model = small_model(4)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    state = {"plan": p}

    def replan(dead):
        new = replan_after_failure(state["plan"], dead,
                                   MILPOptions(time_limit_s=8.0, lns_rounds=0))
        state["plan"] = new
        state["sched"] = new.make_scheduler()
        return state["sched"], new.placement

    sim = Simulator(cluster, model, p.placement, p.make_scheduler(),
                    warmup_s=0.0, horizon_s=600.0, replan_fn=replan)
    sim.fail_node(2.0, "n0")
    trace = [TraceRequest(i, i * 0.05, 128, 16) for i in range(80)]
    m = sim.run(trace)
    assert m.restarts > 0
    assert m.completed_requests > 0
    for name, ns in sim.nodes.items():
        if ns.alive:
            assert abs(ns.kv_used) < 1e-6, (name, ns.kv_used)
    # reservations release on the scheduler that made them: the post-replan
    # estimator must drain to exactly 0 (pre-replan releases never touch it)
    post = state["sched"].kv
    if post is not None:
        for node, usage in post.usage.items():
            assert usage == 0.0, (node, usage)


def test_pipelined_decode_overlaps_return_hop():
    """max_inflight=2 launches the next decode chunk from the final stage
    while tokens travel back to the coordinator: on high-latency links the
    per-token decode latency must drop materially vs the one-outstanding-
    pass walk, with identical token accounting."""
    cluster = make_cluster(("A100", "A100", "A100"), latency_s=50e-3)
    model = small_model(8)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    trace = [TraceRequest(i, 0.0, 64, 32) for i in range(30)]
    lat, decoded = {}, {}
    for depth in (1, 2):
        sched = p.make_scheduler()
        sim = Simulator(cluster, model, p.placement, sched, warmup_s=0.0,
                        horizon_s=600.0, max_inflight=depth)
        m = sim.run(list(trace))
        assert m.completed_requests == len(trace)
        lat[depth] = m.decode_latency["mean"]
        decoded[depth] = m.decoded_tokens
        # the overlap must not break KV accounting
        for name, ns in sim.nodes.items():
            assert abs(ns.kv_used) < 1e-6, (name, ns.kv_used)
    assert decoded[2] == decoded[1]
    assert lat[2] < 0.8 * lat[1], (lat[1], lat[2])
    with pytest.raises(ValueError, match="max_inflight"):
        Simulator(cluster, model, p.placement, p.make_scheduler(),
                  max_inflight=0)


def test_speculative_decode_scales_with_acceptance():
    """Speculation mirrors the runtime: a high-acceptance draft multiplies
    tokens-per-round-trip (and therefore cuts per-token latency on a
    latency-dominated pipeline), a zero-acceptance draft degrades to the
    classic one token per round-trip — while verify work still covers the
    full window and token accounting stays exact."""
    cluster = make_cluster(("A100", "A100", "A100"), latency_s=50e-3)
    model = small_model(8)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    trace = [TraceRequest(i, 0.0, 64, 32) for i in range(30)]
    runs = {}
    for name, kw in (("base", {}),
                     ("hi", dict(spec_tokens=4, spec_acceptance=0.9)),
                     ("lo", dict(spec_tokens=4, spec_acceptance=0.0))):
        sim = Simulator(cluster, model, p.placement, p.make_scheduler(),
                        warmup_s=0.0, horizon_s=600.0, decode_chunk=1, **kw)
        m = sim.run(list(trace))
        assert m.completed_requests == len(trace)
        assert m.decoded_tokens == runs.get("base", m).decoded_tokens
        for nodename, ns in sim.nodes.items():
            assert abs(ns.kv_used) < 1e-6, (nodename, ns.kv_used)
        runs[name] = m
    assert runs["hi"].spec_tokens_per_round_trip > 2.5
    assert runs["hi"].spec_acceptance_rate > 0.6
    assert runs["lo"].spec_tokens_per_round_trip == 1.0
    assert runs["lo"].spec_accepted == 0
    assert runs["hi"].decode_latency["mean"] \
        < 0.6 * runs["base"].decode_latency["mean"]
    # rejected verify work isn't free: zero acceptance must not be faster
    assert runs["lo"].decode_latency["mean"] \
        >= 0.95 * runs["base"].decode_latency["mean"]
    with pytest.raises(ValueError, match="spec_acceptance"):
        Simulator(cluster, model, p.placement, p.make_scheduler(),
                  spec_tokens=4, spec_acceptance=1.5)


def test_straggler_degrades_gracefully():
    cluster = make_cluster(("A100", "A100"))
    model = small_model(4)
    p = plan(cluster, model, MILPOptions(time_limit_s=10.0, lns_rounds=0))
    sim_ok = Simulator(cluster, model, p.placement, p.make_scheduler(),
                       warmup_s=5.0, horizon_s=60.0)
    m_ok = sim_ok.run(make_offline_trace(500, seed=4))
    sim_slow = Simulator(cluster, model, p.placement, p.make_scheduler(),
                         warmup_s=5.0, horizon_s=60.0)
    sim_slow.slow_node(0.0, "n0", 0.05)
    m_slow = sim_slow.run(make_offline_trace(500, seed=4))
    assert m_slow.decoded_tokens < m_ok.decoded_tokens
    assert m_slow.decoded_tokens > 0  # still serving through n1
