"""Preflow-push max flow: unit tests + hypothesis property tests vs networkx."""
import networkx as nx
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.maxflow import FlowNetwork, max_flow, preflow_push


def test_single_edge():
    value, flow = max_flow({("s", "t"): 5.0}, "s", "t")
    assert value == pytest.approx(5.0)
    assert flow[("s", "t")] == pytest.approx(5.0)


def test_series_bottleneck():
    value, _ = max_flow({("s", "a"): 10.0, ("a", "t"): 3.0}, "s", "t")
    assert value == pytest.approx(3.0)


def test_parallel_paths():
    edges = {("s", "a"): 4.0, ("a", "t"): 4.0,
             ("s", "b"): 6.0, ("b", "t"): 5.0}
    value, _ = max_flow(edges, "s", "t")
    assert value == pytest.approx(9.0)


def test_classic_diamond():
    edges = {("s", "a"): 10, ("s", "b"): 10, ("a", "b"): 1,
             ("a", "t"): 8, ("b", "t"): 10}
    value, _ = max_flow(edges, "s", "t")
    # min cut = {a->t, b->t} = 18
    assert value == pytest.approx(18.0)


def test_disconnected():
    value, flow = max_flow({("s", "a"): 5.0, ("b", "t"): 5.0}, "s", "t")
    assert value == pytest.approx(0.0)


def test_missing_source():
    value, flow = max_flow({("a", "b"): 1.0}, "s", "t")
    assert value == 0.0


def _flow_conservation_ok(edges, flow, source, sink):
    from collections import defaultdict
    net = defaultdict(float)
    for (u, v), f in flow.items():
        net[u] -= f
        net[v] += f
    for node, bal in net.items():
        if node in (source, sink):
            continue
        assert abs(bal) < 1e-6, f"conservation violated at {node}: {bal}"


@st.composite
def random_graphs(draw):
    n = draw(st.integers(min_value=2, max_value=10))
    nodes = list(range(n))
    m = draw(st.integers(min_value=1, max_value=min(30, n * (n - 1))))
    edges = {}
    for _ in range(m):
        u = draw(st.integers(min_value=0, max_value=n - 1))
        v = draw(st.integers(min_value=0, max_value=n - 1))
        if u == v:
            continue
        cap = draw(st.floats(min_value=0.1, max_value=100.0,
                             allow_nan=False, allow_infinity=False))
        edges[(u, v)] = edges.get((u, v), 0.0) + cap
    return n, edges


@settings(max_examples=80, deadline=None)
@given(random_graphs())
def test_matches_networkx(graph):
    n, edges = graph
    source, sink = 0, n - 1
    value, flow = max_flow(edges, source, sink)

    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    for (u, v), c in edges.items():
        if G.has_edge(u, v):
            G[u][v]["capacity"] += c
        else:
            G.add_edge(u, v, capacity=c)
    expected = nx.maximum_flow_value(G, source, sink)
    assert value == pytest.approx(expected, rel=1e-6, abs=1e-6)
    # flow legality: capacity + conservation
    for (u, v), f in flow.items():
        assert f <= edges.get((u, v), 0.0) + 1e-6
        assert f >= -1e-9
    _flow_conservation_ok(edges, flow, source, sink)


@settings(max_examples=30, deadline=None)
@given(random_graphs())
def test_flow_value_equals_source_outflow(graph):
    n, edges = graph
    source, sink = 0, n - 1
    value, flow = max_flow(edges, source, sink)
    out = sum(f for (u, v), f in flow.items() if u == source)
    back = sum(f for (u, v), f in flow.items() if v == source)
    assert value == pytest.approx(out - back, rel=1e-6, abs=1e-6)
