"""Int8 KV pages + one-launch variable-context paged decode.

Pins the perf-PR invariants without hypothesis (test_kernels.py carries the
hypothesis ragged-property sweep where that dependency exists):

  * int8 kernel output == a plain-numpy quantized oracle, and stays within
    an absolute bound of the exact (unquantized) attention;
  * the variable-context kernel is exact on ragged batches and its streamed
    page count is the live-page sum, not B x blocks_per_seq;
  * quantized_append round-trips chunked writes against a numpy requantize
    reference and zeroes stale rows in freshly allocated pages;
  * PagePool ensure/release are O(1) bulk free-list ops;
  * pool/profile sizing gives int8 >= 1.8x token capacity at fixed VRAM;
  * default (param-dtype) paged serving stays byte-identical to the dense
    engine through the differential harness, and int8 cluster serving
    completes with every pool drained.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ModelProfile
from repro.kernels.paged_attention import (dense_to_pages,
                                           dequantize_kv_pages,
                                           paged_attention,
                                           quantize_kv_pages,
                                           quantized_append,
                                           streamed_pages_per_step)
from repro.serving import (EngineConfig, PagedEngine, PagePool, Request,
                           page_bytes, pages_for_vram)

from harness import (EC, assert_pools_drained, assert_serves_like_reference,
                     make_disagg_plan, make_plan, random_prompts,
                     serve_on_cluster)


# --- kernel: int8 parity -----------------------------------------------------

def _numpy_quantized_oracle(q, kq, ks, vq, vs, tables, lengths, page):
    """Dequantize with numpy, gather logical KV, exact softmax attention."""
    q, kq, ks, vq, vs = map(np.asarray, (q, kq, ks, vq, vs))
    tables, lengths = np.asarray(tables), np.asarray(lengths)
    B, H, D = q.shape
    KH = kq.shape[2]
    G = H // KH
    k = kq.astype(np.float32) * ks[:, None, :, None]
    v = vq.astype(np.float32) * vs[:, None, :, None]
    out = np.zeros((B, H, D), np.float32)
    for b in range(B):
        L = int(lengths[b])
        nb = -(-L // page)
        kb = k[tables[b, :nb]].reshape(nb * page, KH, D)[:L]
        vb = v[tables[b, :nb]].reshape(nb * page, KH, D)[:L]
        qg = q[b].reshape(KH, G, D)
        s = np.einsum("hgd,shd->hgs", qg, kb) / math.sqrt(D)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        out[b] = np.einsum("hgs,shd->hgd", p, vb).reshape(H, D)
    return out


def test_int8_kernel_matches_numpy_oracle():
    B, H, KH, S, page, D = 3, 8, 2, 256, 32, 64
    key = jax.random.key(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    lengths = jax.random.randint(k4, (B,), 1, S + 1)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    kq, ks = quantize_kv_pages(k_pages)
    vq, vs = quantize_kv_pages(v_pages)
    out = paged_attention(q, kq, vq, tables, lengths,
                          k_scales=ks, v_scales=vs, interpret=True)
    oracle = _numpy_quantized_oracle(q, kq, ks, vq, vs, tables, lengths, page)
    # kernel vs same-quantization oracle: only fp accumulation differs
    np.testing.assert_allclose(np.asarray(out), oracle, rtol=2e-4, atol=2e-4)


def test_int8_kernel_bounded_error_vs_exact():
    """Quantization error stays bounded: int8 output within atol of the
    exact f32 attention over the same KV (unit-normal values)."""
    B, H, KH, S, page, D = 2, 4, 2, 128, 32, 64
    key = jax.random.key(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    lengths = jnp.array([100, 64], jnp.int32)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    exact = paged_attention(q, k_pages, v_pages, tables, lengths,
                            interpret=True)
    kq, ks = quantize_kv_pages(k_pages)
    vq, vs = quantize_kv_pages(v_pages)
    quant = paged_attention(q, kq, vq, tables, lengths,
                            k_scales=ks, v_scales=vs, interpret=True)
    err = np.abs(np.asarray(quant) - np.asarray(exact)).max()
    assert err < 0.08, f"int8 KV error {err:.4f} vs exact attention"


def test_quantize_roundtrip_bound():
    """Per-page per-head absmax: round-trip error <= amax/127 elementwise."""
    pages = jax.random.normal(jax.random.key(2), (5, 16, 3, 32)) * 3.0
    qp, sc = quantize_kv_pages(pages)
    back = dequantize_kv_pages(qp, sc)
    amax = np.abs(np.asarray(pages)).max(axis=(-3, -1), keepdims=False)
    bound = (amax / 127.0)[:, None, :, None] * 1.001 + 1e-7
    assert (np.abs(np.asarray(back - pages)) <= bound).all()


# --- kernel: variable context ------------------------------------------------

RAGGED = [
    (16, [1, 16, 7]),
    (32, [17, 200, 96, 256]),
    (64, [64, 63, 65, 1, 128]),
]


@pytest.mark.parametrize("page,lens", RAGGED)
def test_variable_context_ragged_exact(page, lens):
    """Clamped index_map drops no live token and leaks no dead one."""
    B = len(lens)
    H, KH, D = 4, 2, 64
    S = max(-(-max(lens) // page), 1) * page
    key = jax.random.key(3)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    lengths = jnp.asarray(lens, jnp.int32)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    out = paged_attention(q, k_pages, v_pages, tables, lengths,
                          interpret=True)
    # exact dense oracle over the logical (unpadded) KV
    G = H // KH
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) / math.sqrt(D)
    mask = jnp.arange(S)[None] < lengths[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    ref = jnp.einsum("bhgs,bshd->bhgd",
                     jax.nn.softmax(s, -1), v).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_streamed_pages_live_only():
    """Per step the kernel schedules ceil(len/page) copies per sequence —
    strictly fewer than the dense B x blocks_per_seq grid on ragged loads,
    equal only when every sequence fills its budget."""
    page = 32
    lens = np.array([17, 200, 96], np.int32)
    blocks_per_seq = -(-int(lens.max()) // page)      # 7 (224-token budget)
    live = streamed_pages_per_step(lens, page)
    assert live == 1 + 7 + 3 == 11
    assert live < len(lens) * blocks_per_seq
    full = np.full((4,), 8 * page, np.int32)
    assert streamed_pages_per_step(full, page) == 4 * 8
    # empty sequences still stream their single clamped page
    assert streamed_pages_per_step(np.zeros((2,), np.int32), page) == 2


# --- quantized append --------------------------------------------------------

def test_quantized_append_matches_numpy_requantize():
    """Chunked appends == numpy oracle that requantizes each touched page
    from the exact running history."""
    rng = np.random.RandomState(0)
    page, NP, KH, D, B = 8, 6, 2, 16, 2
    P = 1 + B * NP
    pages = jnp.zeros((P, page, KH, D), jnp.int8)
    scales = jnp.zeros((P, KH), jnp.float32)
    table = jnp.asarray(
        np.arange(1, P).reshape(B, NP).astype(np.int32))
    hist = np.zeros((B, NP * page, KH, D), np.float32)
    start = np.zeros((B,), np.int64)
    for C in (3, 8, 5, 1, 7):
        rows = rng.randn(B, C, KH, D).astype(np.float32)
        pages, scales = quantized_append(
            pages, scales, table, jnp.asarray(start, jnp.int32),
            jnp.asarray(rows))
        for b in range(B):
            hist[b, start[b]:start[b] + C] = rows[b]
        start += C
        # oracle: re-quantize every page from the exact history
        back = np.asarray(dequantize_kv_pages(pages, scales))
        for b in range(B):
            nb = -(-int(start[b]) // page)
            for j in range(nb):
                exact = hist[b, j * page:(j + 1) * page]
                amax = np.abs(exact).max(axis=(0, 2))
                got = back[int(np.asarray(table)[b, j])]
                # written rows within one quantization step of exact
                bound = np.maximum(amax / 127.0, 1e-8)[None, :, None]
                assert (np.abs(got - exact) <= bound * 2.01).all()
    # rows past the write frontier must be exactly zero (no stale garbage
    # inflating a freshly allocated page's absmax)
    b, L = 0, int(start[0])
    nb = -(-L // page)
    tail = np.asarray(dequantize_kv_pages(pages, scales))[
        int(np.asarray(table)[b, nb - 1])].reshape(page, KH, D)
    w = L - (nb - 1) * page
    assert (tail[w:] == 0).all()


# --- pool: O(1) alloc + sizing ----------------------------------------------

def _tiny_cfg():
    from repro.configs import get_smoke_config
    return get_smoke_config("smollm_360m")


def test_pool_bulk_alloc_is_one_op():
    """Growing a slot by 64 blocks is ONE free-list operation, not 64 x
    layers pops; release is one push."""
    cfg = _tiny_cfg()
    page = 4
    pool = PagePool(cfg, num_pages=4096, page_size=page, max_batch=4,
                    max_seq_len=64 * page)
    before = pool.alloc_ops
    assert pool.ensure(0, 64 * page)          # 64 blocks in one call
    assert pool.alloc_ops == before + 1
    got = pool.table[:, 0, :64]
    assert (got > 0).all() and len(np.unique(got)) == got.size
    used = pool.used
    assert used == 64 * pool.num_layers
    pool.release(0)
    assert pool.alloc_ops == before + 2
    assert pool.used == 0 and (pool.table[:, 0] == 0).all()


def test_pool_alloc_order_matches_sequential():
    """Bulk pops hand out the same pages, in the same order, as the old
    one-page-at-a-time loop (layer fastest, block outer)."""
    cfg = _tiny_cfg()
    pool = PagePool(cfg, num_pages=512, page_size=4, max_batch=4,
                    max_seq_len=64)
    L = pool.num_layers
    pool.ensure(0, 9)                          # 3 blocks
    expect = np.arange(1, 1 + 3 * L).reshape(3, L).T
    np.testing.assert_array_equal(pool.table[:, 0, :3], expect)


def test_int8_pool_capacity_ratio():
    cfg = _tiny_cfg()
    vram = 4e9
    base = pages_for_vram(cfg, vram, page_size=16)
    quant = pages_for_vram(cfg, vram, page_size=16, kv_dtype="int8")
    assert quant / max(base, 1) >= 1.8
    # page_bytes math: int8 = elements at 1 byte + 2 f32 scale rows
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    assert page_bytes(cfg, 16, "int8") == 2 * 16 * kh * hd + 8 * kh
    elt = {"bfloat16": 2, "float32": 4}[cfg.param_dtype]
    assert page_bytes(cfg, 16) == 2 * 16 * kh * hd * elt


def test_model_profile_int8_kv_sizing():
    """Planner/simulator capacity model sees the same ~2x the engines get."""
    kw = dict(num_layers=8, d_model=512, d_ff=2048, vocab=1000,
              n_kv_heads=4, head_dim=64)
    base = ModelProfile.from_dims("m", **kw)
    quant = ModelProfile.from_dims("m", kv_dtype="int8", kv_page_size=16,
                                   **kw)
    r = base.kv_bytes_per_token_layer / quant.kv_bytes_per_token_layer
    assert r >= 1.8
    with pytest.raises(ValueError):
        ModelProfile.from_dims("m", kv_dtype="fp4", **kw)


def test_pool_rejects_unknown_kv_dtype():
    cfg = _tiny_cfg()
    with pytest.raises(ValueError):
        PagePool(cfg, num_pages=512, page_size=4, max_batch=2,
                 max_seq_len=16, kv_dtype="fp8")


# --- engines -----------------------------------------------------------------

def test_paged_engine_int8_completes_and_drains(gqa_model):
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=4, max_len=64, prompt_len=16)
    eng = PagedEngine(cfg, params, ec, page_size=16, kv_dtype="int8")
    assert eng.pool.quantized and eng.pool.k.dtype == jnp.int8
    prompts = random_prompts(cfg, (10, 5, 16, 12), seed=0)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_iters=200)
    assert all(r.done and len(r.output) == 6 for r in reqs)
    assert eng.pool.used == 0


def test_default_paged_serving_stays_byte_identical(gqa_model, reference):
    """The PR's do-no-harm pin: with kv_dtype unset, multi-stage paged
    serving through the differential harness is still byte-identical to the
    single dense engine."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    assert_serves_like_reference(cfg, params, p, prompts, ref, paged=True)


def test_cluster_int8_completes_and_drains(gqa_model):
    cfg, params = gqa_model
    prompts = random_prompts(cfg, (10, 5, 16), seed=1)
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt, reqs = serve_on_cluster(cfg, params, p, prompts, paged=True,
                                kv_dtype="int8", max_new_tokens=5)
    assert all(r.done and len(r.output) == 5 for r in reqs)
    assert_pools_drained(rt)


def test_int8_disaggregated_matches_mixed_cluster(gqa_model):
    """The int8 handoff tolerance check: quantized pages + scales travel
    verbatim over the peer link, so a disaggregated int8 run must emit
    token-for-token what a mixed int8 cluster with the same decode split
    emits (quantization error is identical — the pages are the same
    bytes)."""
    from repro.serving import InProcessTransport
    cfg, params = gqa_model
    prompts = random_prompts(cfg, (10, 5, 16), seed=1)
    pm = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    _, reqm = serve_on_cluster(cfg, params, pm, prompts, paged=True,
                               kv_dtype="int8", max_new_tokens=5)
    refq = [r.output for r in reqm]
    pd = make_disagg_plan(cfg, {"n0": (0, 4)}, {"n1": (0, 2), "n2": (2, 4)})
    rt, reqd = serve_on_cluster(cfg, params, pd, prompts, paged=True,
                                kv_dtype="int8", max_new_tokens=5,
                                transport=InProcessTransport(
                                    default_delay_s=1e-3))
    assert rt.disaggregated
    assert [r.output for r in reqd] == refq
    assert_pools_drained(rt)
