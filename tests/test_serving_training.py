"""Serving engine + training substrate tests."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init, loss_fn
from repro.serving import Engine, EngineConfig, Request
from repro.training import (AsyncCheckpointer, DataConfig, OptimizerConfig,
                            TrainConfig, init_train_state, latest_step,
                            make_batch, make_train_step, restore, save)


# --- serving -----------------------------------------------------------------

def test_engine_serves_batched_requests():
    cfg = get_smoke_config("smollm_360m")
    params = init(cfg, jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=4, max_len=64,
                                           prompt_len=16))
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(10,)),
                    max_new_tokens=6) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_iters=200)
    for r in reqs:
        assert r.done
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_engine_greedy_matches_decode_reference():
    """Engine greedy decode must equal a hand-rolled prefill+decode loop."""
    from repro.models import decode_step, prefill
    cfg = get_smoke_config("olmo_1b")
    params = init(cfg, jax.random.key(1))
    prompt = np.arange(12) % cfg.vocab_size

    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_len=64,
                                           prompt_len=16))
    req = Request(0, prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_until_done(max_iters=50)

    tok = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, caches = prefill(cfg, params, tok, max_len=64)
    out = [int(jnp.argmax(logits[0]))]
    pos = jnp.asarray([12], jnp.int32)
    for t in range(4):
        logits, caches = decode_step(cfg, params,
                                     jnp.asarray([out[-1]], jnp.int32),
                                     caches, pos + t)
        out.append(int(jnp.argmax(logits[0])))
    assert req.output == out


# --- optimizers ----------------------------------------------------------------

@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_training_reduces_loss(opt_name):
    """Overfit a fixed batch: loss must collapse (validates grads+optimizer).
    (Fresh-batch generalization needs induction heads — too slow for CI.)"""
    cfg = get_smoke_config("smollm_360m")
    params = init(cfg, jax.random.key(0))
    tc = TrainConfig(optimizer=OptimizerConfig(
        name=opt_name, lr=3e-3, warmup_steps=5, total_steps=1000,
        weight_decay=0.0), remat="none")
    step_fn = jax.jit(make_train_step(cfg, tc))
    opt_state = init_train_state(cfg, tc, params)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=4, seq_len=32,
                    seed=3)
    batch = make_batch(dc, 0)
    losses = []
    for s in range(100):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.5, losses[::20]


def test_microbatched_grad_matches_full():
    cfg = get_smoke_config("olmo_1b")
    params = init(cfg, jax.random.key(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=8, seq_len=16)
    batch = make_batch(dc, 0)
    tc1 = TrainConfig(optimizer=OptimizerConfig(lr=1e-3), microbatches=1,
                      remat="none")
    tc4 = TrainConfig(optimizer=OptimizerConfig(lr=1e-3), microbatches=4,
                      remat="none")
    opt1 = init_train_state(cfg, tc1, params)
    opt4 = init_train_state(cfg, tc4, params)
    p1, _, m1 = jax.jit(make_train_step(cfg, tc1))(params, opt1, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, tc4))(params, opt4, batch)
    l1 = jax.tree.leaves(p1)[0]
    l4 = jax.tree.leaves(p4)[0]
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l4, np.float32), rtol=2e-2,
                               atol=2e-4)


# --- checkpointing ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("smollm_360m")
    params = init(cfg, jax.random.key(0))
    d = str(tmp_path / "ckpt")
    save(d, 7, params, metadata={"data_step": 7})
    assert latest_step(d) == 7
    restored, step, meta = restore(d, None, params)
    assert step == 7 and meta["data_step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_restart_resumes_training(tmp_path):
    """Train 6 steps with a save at 3, crash, restore, continue — final
    params must equal an uninterrupted 6-step run (fault tolerance)."""
    cfg = get_smoke_config("olmo_1b")
    tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                               total_steps=10), remat="none")
    dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=4, seq_len=16)
    step_fn = jax.jit(make_train_step(cfg, tc))

    def run(n0, n1, params, opt_state):
        for s in range(n0, n1):
            params, opt_state, _ = step_fn(params, opt_state, make_batch(dc, s))
        return params, opt_state

    params0 = init(cfg, jax.random.key(0))
    opt0 = init_train_state(cfg, tc, params0)
    ref_params, _ = run(0, 6, params0, opt0)

    params, opt = run(0, 3, params0, opt0)
    d = str(tmp_path / "ckpt")
    save(d, 3, {"params": params, "opt": opt}, metadata={"data_step": 3})
    # "crash"; restore
    state, step, meta = restore(d, None, {"params": params, "opt": opt})
    params2, _ = run(meta["data_step"], 6, state["params"], state["opt"])
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(params2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)


def test_async_checkpointer(tmp_path):
    cfg = get_smoke_config("smollm_360m")
    params = init(cfg, jax.random.key(0))
    ck = AsyncCheckpointer(str(tmp_path / "ckpt"), keep=2)
    for s in (1, 2, 3):
        ck.save_async(s, params, metadata={"s": s})
    ck.wait()
    assert latest_step(str(tmp_path / "ckpt")) == 3
    # gc kept only 2
    names = [n for n in os.listdir(str(tmp_path / "ckpt"))
             if n.startswith("step_")]
    assert len(names) == 2
