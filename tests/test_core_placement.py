"""Graph abstraction, heuristic placements, and MILP placement tests."""
import pytest

from repro.core import (COORDINATOR, LLAMA_30B, LLAMA_70B, MILPOptions,
                        ModelProfile, Placement, LayerRange, build_graph,
                        compute_upper_bound, make_distributed_cluster,
                        make_high_heterogeneity_cluster, make_single_cluster,
                        make_tpu_pod_cluster, petals_placement,
                        placement_throughput, plan,
                        separate_pipelines_placement, solve_placement,
                        swarm_placement)
from repro.core.cluster import DEVICE_PROFILES, ClusterSpec, LinkSpec, NodeSpec
from repro.core.cluster import _full_mesh_links


def tiny_cluster(devs=("A100", "T4", "T4")):
    nodes, regions = {}, {COORDINATOR: "r0"}
    for i, d in enumerate(devs):
        name = f"n{i}"
        nodes[name] = NodeSpec(name, DEVICE_PROFILES[d], region="r0")
        regions[name] = "r0"
    links = _full_mesh_links(list(nodes), regions, 10e9 / 8, 1e-3, 10e9 / 8, 1e-3)
    return ClusterSpec(nodes=nodes, links=links)


def small_model(num_layers=8):
    return ModelProfile.from_dims("toy", num_layers=num_layers, d_model=4096,
                                  d_ff=11008, vocab=32000, n_kv_heads=32,
                                  head_dim=128)


# --- placement heuristics ---------------------------------------------------

def test_swarm_placement_valid():
    cluster = make_single_cluster()
    p = swarm_placement(cluster, LLAMA_30B)
    assert p.validate() == []


def test_petals_placement_valid():
    cluster = make_single_cluster()
    p = petals_placement(cluster, LLAMA_30B)
    assert p.validate() == []


def test_separate_pipelines_valid_30b():
    cluster = make_single_cluster()
    p = separate_pipelines_placement(cluster, LLAMA_30B)
    assert p.validate() == []


def test_separate_pipelines_mixed_tail():
    cluster = make_high_heterogeneity_cluster()
    p = separate_pipelines_placement(cluster, LLAMA_70B, allow_mixed_tail=True)
    assert p.validate() == []


# --- graph abstraction -------------------------------------------------------

def test_graph_throughput_single_node_bound():
    """One node holding the whole model: throughput == node capacity."""
    cluster = tiny_cluster(("A100",))
    model = small_model(4)
    p = Placement({"n0": LayerRange(0, 4)}, 4)
    tput = placement_throughput(cluster, model, p)
    expected = cluster.node_token_throughput("n0", model, 4)
    # coordinator links are far faster than compute here
    assert tput == pytest.approx(expected, rel=1e-6)


def test_graph_throughput_additive_replicas():
    """Two identical nodes each holding the full model: throughput doubles."""
    cluster = tiny_cluster(("T4", "T4"))
    model = small_model(2)
    p = Placement({"n0": LayerRange(0, 2), "n1": LayerRange(0, 2)}, 2)
    tput = placement_throughput(cluster, model, p)
    single = cluster.node_token_throughput("n0", model, 2)
    assert tput == pytest.approx(2 * single, rel=1e-6)


def test_graph_pipeline_bottleneck():
    """Two-stage pipeline: throughput == min(stage capacities)."""
    cluster = tiny_cluster(("A100", "T4"))
    model = small_model(8)
    p = Placement({"n0": LayerRange(0, 4), "n1": LayerRange(4, 8)}, 8)
    tput = placement_throughput(cluster, model, p)
    c0 = cluster.node_token_throughput("n0", model, 4)
    c1 = cluster.node_token_throughput("n1", model, 4)
    link = cluster.link_token_capacity("n0", "n1", model)
    assert tput == pytest.approx(min(c0, c1, link), rel=1e-6)


def test_invalid_placement_zero_throughput():
    cluster = tiny_cluster(("A100",))
    model = small_model(8)
    p = Placement({"n0": LayerRange(0, 4)}, 8)  # misses layers 4..8
    assert placement_throughput(cluster, model, p) == 0.0


def test_partial_inference_allows_overlap():
    """n0 holds [0,6), n1 holds [4,8): valid only with partial inference."""
    cluster = tiny_cluster(("A100", "A100"))
    model = small_model(8)
    p = Placement({"n0": LayerRange(0, 6), "n1": LayerRange(4, 8)}, 8)
    with_partial = placement_throughput(cluster, model, p, True)
    without = placement_throughput(cluster, model, p, False)
    assert with_partial > 0.0
    assert without == 0.0


# --- MILP --------------------------------------------------------------------

def test_milp_beats_or_matches_heuristics_small():
    cluster = tiny_cluster(("A100", "L4", "T4", "T4"))
    model = small_model(8)
    opts = MILPOptions(time_limit_s=20.0, lns_rounds=0)
    result = solve_placement(cluster, model, opts)
    assert result.placement.validate() == []
    for name, fn in [("swarm", swarm_placement), ("petals", petals_placement)]:
        t = placement_throughput(cluster, model, fn(cluster, model))
        assert result.actual_throughput >= t * 0.999, name


def test_milp_respects_upper_bound():
    cluster = tiny_cluster(("T4", "T4"))
    model = small_model(4)
    result = solve_placement(cluster, model,
                             MILPOptions(time_limit_s=10.0, lns_rounds=0))
    ub = compute_upper_bound(cluster, model)
    assert result.actual_throughput <= ub * 1.001


def test_milp_single_node_holds_all():
    cluster = tiny_cluster(("A100",))
    model = small_model(4)
    result = solve_placement(cluster, model,
                             MILPOptions(time_limit_s=10.0, lns_rounds=0))
    assert result.placement.assignment["n0"] == LayerRange(0, 4)


def test_plan_end_to_end():
    cluster = tiny_cluster(("A100", "L4", "T4", "T4"))
    model = small_model(8)
    p = plan(cluster, model, MILPOptions(time_limit_s=20.0, lns_rounds=1))
    assert p.throughput > 0
    # flows out of coordinator equal total throughput
    src_flow = sum(f for (u, v), f in p.flows.items() if u == COORDINATOR)
    assert src_flow == pytest.approx(p.throughput, rel=1e-6)


def test_milp_matches_bruteforce_on_tiny_cluster():
    """Exhaustively enumerate placements on a tiny instance; the MILP must
    find a placement whose max flow matches the brute-force optimum."""
    import itertools
    cluster = tiny_cluster(("T4", "T4", "L4"))
    model = small_model(4)
    opts = MILPOptions(time_limit_s=30.0, lns_rounds=0, fgls_rounds=0,
                       prune_degree=None, mip_rel_gap=1e-6)
    result = solve_placement(cluster, model, opts)

    names = sorted(cluster.nodes)
    k_of = {n: min(4, cluster.max_layers_on(n, model, 0.5)) for n in names}
    ranges = {n: [LayerRange(s, s + l)
                  for l in range(1, k_of[n] + 1)
                  for s in range(0, 4 - l + 1)] for n in names}
    best = 0.0
    for combo in itertools.product(*(ranges[n] for n in names)):
        p = Placement(dict(zip(names, combo)), 4)
        if p.validate():
            continue
        best = max(best, placement_throughput(cluster, model, p))
    assert result.actual_throughput == pytest.approx(best, rel=1e-4)


def test_fgls_improves_or_keeps_heuristic():
    from repro.core.local_search import FGLSOptions, refine_placement
    cluster = make_single_cluster()
    p0 = petals_placement(cluster, LLAMA_70B)
    t0 = placement_throughput(cluster, LLAMA_70B, p0)
    p1, t1, _ = refine_placement(cluster, LLAMA_70B, p0, FGLSOptions(rounds=20))
    assert t1 >= t0 * 0.999
    assert p1.validate() == []
