"""Paged-KV engine tests: dense-engine equivalence, chunked long-prompt
prefill (no truncation), pool accounting, admission control/preemption, and
the engine bugfix regressions (truncation, max_len, max_new_tokens=1).

The smoke model + its f32 cast come from tests/harness.py //
tests/conftest.py (``gqa_model`` is session-scoped there)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import decode_step, init, prefill
from repro.models.paged import num_paged_layers
from repro.serving import Engine, EngineConfig, PagedEngine, Request

from harness import f32, random_prompts


def _reference_greedy(cfg, params, prompt, n_tokens, max_len=64):
    """Hand-rolled prefill + decode loop (greedy)."""
    tok = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, caches = prefill(cfg, params, tok, max_len=max_len)
    out = [int(jnp.argmax(logits[0]))]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    for t in range(n_tokens - 1):
        logits, caches = decode_step(cfg, params,
                                     jnp.asarray([out[-1]], jnp.int32),
                                     caches, pos + t)
        out.append(int(jnp.argmax(logits[0])))
    return out


# --- equivalence -------------------------------------------------------------

def test_paged_matches_dense_engine_greedy(gqa_model):
    """Paged engine must match the dense engine token-for-token at temp 0,
    with several concurrent requests, and free every page at the end."""
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=4, max_len=64, prompt_len=16)
    prompts = random_prompts(cfg, (10, 5, 16, 12, 7, 14), seed=0)

    dense = Engine(cfg, params, ec)
    paged = PagedEngine(cfg, params, ec, page_size=16)
    d_reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    p_reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in d_reqs:
        dense.submit(r)
    for r in p_reqs:
        paged.submit(r)
    dense.run_until_done(max_iters=200)
    paged.run_until_done(max_iters=200)
    for dr, pr in zip(d_reqs, p_reqs):
        assert dr.done and pr.done
        assert pr.output == dr.output, (pr.request_id, pr.output, dr.output)
    assert paged.pool.used == 0


def test_paged_hybrid_stack_dense_fallback():
    """Stack mixing mamba/MoE blocks with GQA attention: paged decode for
    the attention layers + dense fallback elsewhere still matches the dense
    engine token-for-token."""
    cfg = f32(get_smoke_config("jamba_1_5_large_398b"))
    assert 0 < num_paged_layers(cfg) < cfg.num_layers  # genuinely hybrid
    params = init(cfg, jax.random.key(2))
    prompt = random_prompts(cfg, (11,), seed=1)[0]

    dense = Engine(cfg, params, EngineConfig(max_batch=2, max_len=48,
                                             prompt_len=16))
    paged = PagedEngine(cfg, params, EngineConfig(max_batch=2, max_len=48,
                                                  prompt_len=16), page_size=8)
    r1, r2 = Request(0, prompt, max_new_tokens=6), \
        Request(0, prompt, max_new_tokens=6)
    dense.submit(r1)
    paged.submit(r2)
    dense.run_until_done(50)
    paged.run_until_done(50)
    assert r2.output == r1.output
    assert paged.pool.used == 0


# --- long prompts (truncation bugfix) ---------------------------------------

def test_paged_long_prompt_not_truncated(gqa_model):
    """A prompt 3x prompt_len prefills in chunks — every token must count
    (the dense engine used to silently keep only the last prompt_len)."""
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=2, max_len=64, prompt_len=16)
    prompt = (np.arange(48) * 7) % cfg.vocab_size        # 3x prompt_len
    eng = PagedEngine(cfg, params, ec, page_size=16)
    req = Request(0, prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_until_done(max_iters=50)
    assert req.done
    assert req.output == _reference_greedy(cfg, params, prompt, 5)
    assert eng.pool.used == 0


def test_dense_engine_refuses_to_truncate(gqa_model):
    """Regression: Engine._admit used to drop prompt[:-prompt_len] silently;
    it must now raise instead."""
    cfg, params = gqa_model
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_len=64,
                                           prompt_len=16))
    with pytest.raises(ValueError, match="truncate"):
        eng.submit(Request(0, np.arange(48) % cfg.vocab_size))


@pytest.mark.parametrize("engine_cls", [Engine, PagedEngine])
def test_empty_prompt_rejected(gqa_model, engine_cls):
    cfg, params = gqa_model
    eng = engine_cls(cfg, params, EngineConfig(max_batch=2, max_len=32,
                                               prompt_len=16))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(0, np.zeros((0,), np.int32)))


def test_paged_rejects_prompt_over_budget(gqa_model):
    cfg, params = gqa_model
    eng = PagedEngine(cfg, params, EngineConfig(max_batch=2, max_len=32,
                                                prompt_len=16))
    with pytest.raises(ValueError, match="budget"):
        eng.submit(Request(0, np.arange(40) % cfg.vocab_size))


# --- max_len enforcement (out-of-range decode bugfix) ------------------------

@pytest.mark.parametrize("engine_cls", [Engine, PagedEngine])
def test_request_terminates_at_length_budget(gqa_model, engine_cls):
    """prompt + output exceeding max_len must finish cleanly at the budget
    (positions used to grow past the cache and write out of range)."""
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=2, max_len=24, prompt_len=16)
    prompt = np.arange(10) % cfg.vocab_size
    eng = engine_cls(cfg, params, ec)
    req = Request(0, prompt, max_new_tokens=1000)
    eng.submit(req)
    eng.run_until_done(max_iters=100)
    assert req.done and req.finish_reason == "length"
    # prefill emits 1 token at pos S, decode fills positions S..max_len-1
    assert len(req.output) == ec.max_len - len(prompt) + 1
    assert not eng.active.any()
    # budget-terminated greedy output must equal an unbounded reference's
    # first tokens (i.e. termination didn't corrupt the cache mid-stream)
    ref = _reference_greedy(cfg, params, prompt, len(req.output), max_len=64)
    assert req.output == ref


# --- first-token bookkeeping (max_new_tokens=1 / eos bugfix) -----------------

@pytest.mark.parametrize("engine_cls", [Engine, PagedEngine])
def test_single_token_request_never_seats(gqa_model, engine_cls):
    """A max_new_tokens=1 request is fully served by prefill: it must not
    occupy a slot nor decode an extra token."""
    cfg, params = gqa_model
    eng = engine_cls(cfg, params, EngineConfig(max_batch=2, max_len=64,
                                               prompt_len=16))
    req = Request(0, np.arange(8) % cfg.vocab_size, max_new_tokens=1)
    eng.submit(req)
    produced = eng.step()
    assert req.done and len(req.output) == 1
    assert produced == 0 and not eng.active.any()
    if engine_cls is PagedEngine:
        assert eng.pool.used == 0


def test_eos_on_first_token_finishes_immediately(gqa_model):
    cfg, params = gqa_model
    prompt = np.arange(8) % cfg.vocab_size
    # find what greedy emits first, then make that the eos token
    first = _reference_greedy(cfg, params, prompt, 1)[0]
    eng = PagedEngine(cfg, params, EngineConfig(max_batch=2, max_len=64,
                                                prompt_len=16,
                                                eos_token=first))
    req = Request(0, prompt, max_new_tokens=32)
    eng.submit(req)
    eng.step()
    assert req.done and req.output == [first]
    assert req.finish_reason == "stop"
    assert eng.pool.used == 0


# --- pool admission control / preemption -------------------------------------

def test_pool_admission_blocks_then_completes(gqa_model):
    """A pool holding ~2 requests' pages with 4 slots must serve 6 requests
    to completion by blocking admission, never overflowing."""
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=4, max_len=32, prompt_len=16)
    L = num_paged_layers(cfg)
    pool_pages = 1 + 2 * (32 // 16) * L      # two full budgets + scratch
    eng = PagedEngine(cfg, params, ec, num_pages=pool_pages, page_size=16)
    rng = np.random.RandomState(3)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(9,)),
                    max_new_tokens=6) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_iters=500)
    for r in reqs:
        assert r.done and len(r.output) == 6
    assert eng.pool.used == 0


def test_pool_preempts_newest_when_exhausted(gqa_model):
    """With a pool that fits exactly one full-budget request, concurrent
    decodes must preempt (recompute) rather than overflow — and everyone
    still finishes with the right number of tokens."""
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=4, max_len=32, prompt_len=16)
    L = num_paged_layers(cfg)
    prompts = [np.random.RandomState(4).randint(0, cfg.vocab_size, size=(10,))
               for _ in range(4)]
    eng = PagedEngine(cfg, params, ec, num_pages=1 + (32 // 16) * L,
                      page_size=16)
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_iters=500)
    assert any(r.preemptions > 0 for r in reqs)
    # recompute-on-readmit keeps already-generated tokens: greedy output
    # must equal a run with an unconstrained pool
    calm = PagedEngine(cfg, params, ec, page_size=16)
    calm_reqs = [Request(i, p, max_new_tokens=8)
                 for i, p in enumerate(prompts)]
    for r in calm_reqs:
        calm.submit(r)
    calm.run_until_done(max_iters=500)
    for r, cr in zip(reqs, calm_reqs):
        assert r.done and len(r.output) == 8
        assert r.output == cr.output, (r.request_id, r.output, cr.output)
    assert eng.pool.used == 0


def test_pool_too_small_for_one_request_raises(gqa_model):
    cfg, params = gqa_model
    with pytest.raises(ValueError, match="cannot hold"):
        PagedEngine(cfg, params, EngineConfig(max_batch=2, max_len=64,
                                              prompt_len=16), num_pages=3)
