"""Pallas kernel validation (interpret=True on CPU) against jnp oracles.

Per assignment: sweep shapes/dtypes per kernel, assert_allclose vs ref.py.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)
from repro.kernels.paged_attention import (dense_to_pages, paged_attention,
                                           paged_attention_ref,
                                           quantize_kv_pages,
                                           streamed_pages_per_step)

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _mk_qkv(key, B, H, KH, Sq, Sk, D, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, KH, Sk, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, KH, Sk, D), jnp.float32).astype(dtype)
    return q, k, v


# --- flash attention sweeps --------------------------------------------------

FLASH_SHAPES = [
    # B, H, KH, Sq, Sk, D, causal, window
    (1, 4, 4, 128, 128, 64, True, 0),
    (2, 8, 2, 256, 256, 128, True, 0),       # GQA
    (1, 4, 1, 128, 128, 128, True, 0),       # MQA
    (2, 4, 4, 128, 128, 64, False, 0),       # bidirectional
    (1, 4, 2, 256, 256, 64, True, 100),      # sliding window
    (1, 2, 2, 200, 200, 64, True, 0),        # ragged (pad to blocks)
    (1, 2, 2, 96, 160, 64, False, 0),        # cross lengths
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", FLASH_SHAPES)
def test_flash_attention_matches_ref(shape, dtype):
    B, H, KH, Sq, Sk, D, causal, window = shape
    q, k, v = _mk_qkv(jax.random.key(0), B, H, KH, Sq, Sk, D, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    q, k, v = _mk_qkv(jax.random.key(1), 1, 4, 2, 256, 256, 64, jnp.float32)
    outs = []
    for bq, bk in [(64, 64), (128, 64), (64, 128), (128, 128), (256, 256)]:
        outs.append(flash_attention(q, k, v, causal=True, block_q=bq,
                                    block_kv=bk, interpret=True))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(0, 3), st.booleans())
def test_flash_attention_property(b, g_pow, causal):
    """Random GQA configs vs oracle (hypothesis sweep)."""
    KH = 2
    H = KH * (2 ** g_pow)
    q, k, v = _mk_qkv(jax.random.key(b * 7 + g_pow), b, H, KH, 128, 128, 64,
                      jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# --- paged attention sweeps --------------------------------------------------

PAGED_SHAPES = [
    # B, H, KH, S(max), page, D
    (2, 4, 4, 256, 64, 64),
    (3, 8, 2, 256, 64, 128),                 # GQA
    (1, 4, 1, 512, 128, 64),                 # MQA
    (4, 2, 2, 128, 32, 128),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_paged_attention_matches_ref(shape, dtype):
    B, H, KH, S, page, D = shape
    key = jax.random.key(42)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, S, KH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, S, KH, D), jnp.float32).astype(dtype)
    lengths = jax.random.randint(k4, (B,), 1, S + 1)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    out = paged_attention(q, k_pages, v_pages, tables, lengths,
                          interpret=True)
    ref = paged_attention_ref(q, k_pages, v_pages, tables, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_paged_attention_scrambled_pages():
    """Same logical KV, different physical page layout -> same output
    (the whole point of paging)."""
    B, H, KH, S, page, D = 2, 4, 2, 256, 64, 64
    key = jax.random.key(7)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    lengths = jnp.array([200, 130], jnp.int32)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    out1 = paged_attention(q, k_pages, v_pages, tables, lengths,
                           interpret=True)
    # scramble physical page order with a permutation
    P = k_pages.shape[0]
    perm = jax.random.permutation(jax.random.key(9), P)
    inv = jnp.argsort(perm)
    out2 = paged_attention(q, k_pages[perm], v_pages[perm], inv[tables],
                           lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4),                       # batch
       st.sampled_from([16, 32, 64]),           # page size
       st.integers(1, 6),                       # blocks per sequence budget
       st.integers(0, 2 ** 30))                 # length seed
def test_paged_attention_ragged_property(b, page, nblk, seed):
    """Variable-context kernel == oracle over ragged lengths x page counts.

    The clamped index_map only schedules copies for a sequence's live pages;
    this sweep pins that the truncation never drops a live token or lets a
    dead one leak in, across arbitrary ragged length mixes."""
    H, KH, D = 4, 2, 64
    S = page * nblk
    key = jax.random.key(seed % (2 ** 31 - 1))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (b, H, D))
    k = jax.random.normal(k2, (b, S, KH, D))
    v = jax.random.normal(k3, (b, S, KH, D))
    lengths = jax.random.randint(k4, (b,), 1, S + 1)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    out = paged_attention(q, k_pages, v_pages, tables, lengths,
                          interpret=True)
    ref = paged_attention_ref(q, k_pages, v_pages, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    # live-page traffic accounting: never more than the dense grid
    streamed = streamed_pages_per_step(np.asarray(lengths), page)
    assert streamed <= b * nblk


@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_paged_attention_int8_matches_ref(shape):
    """Quantized kernel == oracle run on the *dequantized* pages — the
    in-VMEM dequant must be numerically transparent."""
    B, H, KH, S, page, D = shape
    key = jax.random.key(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    lengths = jax.random.randint(k4, (B,), 1, S + 1)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    kq, ks = quantize_kv_pages(k_pages)
    vq, vs = quantize_kv_pages(v_pages)
    out = paged_attention(q, kq, vq, tables, lengths,
                          k_scales=ks, v_scales=vs, interpret=True)
    ref = paged_attention_ref(q, kq, vq, tables, lengths,
                              k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_matches_dense_decode():
    """Paged decode == dense cache attention at the same positions."""
    import math
    B, H, KH, S, page, D = 2, 8, 4, 128, 32, 64
    key = jax.random.key(11)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    lengths = jnp.array([100, 64], jnp.int32)
    k_pages, v_pages, tables = dense_to_pages(k, v, lengths, page)
    out = paged_attention(q, k_pages, v_pages, tables, lengths,
                          interpret=True)
    # dense reference
    G = H // KH
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) / math.sqrt(D)
    mask = jnp.arange(S)[None] < lengths[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bhgs,bshd->bhgd", p, v).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
