"""Shared fixtures: one smoke GQA model + its single-engine greedy
reference, session-scoped so the runtime and paged-engine tests stop
re-initialising params per module."""
import pytest

import jax


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long end-to-end tests (multi-process workers); "
                   "deselect with -m 'not slow'")

from repro.models import init

from harness import EC, f32, random_prompts, reference_outputs


@pytest.fixture(scope="session")
def gqa_model():
    from repro.configs import get_smoke_config
    cfg = f32(get_smoke_config("smollm_360m"))
    return cfg, init(cfg, jax.random.key(0))


@pytest.fixture(scope="session")
def reference(gqa_model):
    """Prompts + greedy outputs from a single full-model dense engine."""
    cfg, params = gqa_model
    prompts = random_prompts(cfg, (10, 5, 16, 12), seed=0)
    return prompts, reference_outputs(cfg, params, prompts, ec=EC,
                                      max_new_tokens=6)
