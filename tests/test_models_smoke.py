"""Per-architecture smoke tests: reduced configs, one forward + one decode
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import decode_step, forward, init, init_caches, loss_fn, prefill

jax.config.update("jax_platform_name", "cpu")


def _batch_inputs(cfg, batch=2, seq=24, seed=0):
    rng = np.random.RandomState(seed)
    tokens = rng.randint(0, cfg.vocab_size, size=(batch, seq)).astype(np.int32)
    out = {"tokens": jnp.asarray(tokens)}
    if cfg.is_encoder_decoder:
        frames = rng.randn(batch, 16, cfg.d_model).astype(np.float32)
        out["encoder_frames"] = jnp.asarray(frames, dtype=jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init(cfg, jax.random.key(0))
    inputs = _batch_inputs(cfg)
    logits, aux = forward(cfg, params, inputs["tokens"],
                          encoder_frames=inputs.get("encoder_frames"))
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_finite(arch):
    cfg = get_smoke_config(arch)
    params = init(cfg, jax.random.key(1))
    inputs = _batch_inputs(cfg)
    batch = {"tokens": inputs["tokens"],
             "labels": inputs["tokens"]}
    if "encoder_frames" in inputs:
        batch["encoder_frames"] = inputs["encoder_frames"]
    loss, metrics = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # gradient exists and is finite for a couple of leaves
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    leaf = jax.tree.leaves(grads)[0]
    assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Decode path consistency: token-by-token decode logits must match the
    full-sequence forward logits (same params, same tokens)."""
    cfg = get_smoke_config(arch)
    params = init(cfg, jax.random.key(2))
    inputs = _batch_inputs(cfg, batch=2, seq=12)
    tokens = inputs["tokens"]
    ref_logits, _ = forward(cfg, params, tokens,
                            encoder_frames=inputs.get("encoder_frames"))

    prompt, rest = tokens[:, :8], tokens[:, 8:]
    logits_p, caches = prefill(cfg, params, prompt, max_len=32,
                               encoder_frames=inputs.get("encoder_frames"))
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(ref_logits[:, 7], np.float32), rtol=0.15, atol=0.3)

    pos = jnp.full((2,), 8, jnp.int32)
    logits_d = logits_p
    for t in range(rest.shape[1]):
        logits_d, caches = decode_step(cfg, params, rest[:, t], caches,
                                       pos + t)
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(ref_logits[:, 8 + t], np.float32), rtol=0.15, atol=0.3)


def test_param_counts_match_assignment_scale():
    """Full configs should land in the right parameter-count ballpark."""
    from repro.configs import get_config
    expectations = {
        "jamba_1_5_large_398b": (300e9, 500e9),
        "deepseek_v2_236b": (180e9, 300e9),
        "mixtral_8x22b": (110e9, 180e9),
        "chameleon_34b": (28e9, 42e9),
        "gemma3_12b": (9e9, 16e9),
        "starcoder2_7b": (6e9, 9e9),
        "olmo_1b": (0.8e9, 1.6e9),
        "smollm_360m": (0.25e9, 0.5e9),
        "xlstm_350m": (0.2e9, 0.6e9),
        "whisper_tiny": (20e6, 80e6),
    }
    for arch, (lo, hi) in expectations.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
