"""Live autoscaling over a running ClusterRuntime, driven synchronously
through ``Autoscaler.tick()`` on the virtual clock: a sustained load step
must trigger a mix solve + ``apply_plan`` scale-up (incumbent nodes keep
their layer ranges, so requests already running finish byte-identical),
sustained underload must drain + retire the priciest redundant node, and a
measured straggler must shift IWRR flow away via
``reweight_for_straggler`` — its first real caller — without rebuilding
engines or requeueing anything."""
import dataclasses

import pytest

from repro.core import (COORDINATOR, LayerRange, Placement, plan,
                        reweight_for_straggler)
from repro.core.cluster import DEVICE_PROFILES
from repro.core.mix_planner import Bucket, TrafficProfile
from repro.serving import (Autoscaler, ClusterRuntime, InProcessTransport,
                           Request)

from harness import (EC, assert_pools_drained, make_cluster, make_plan,
                     small_model)


def _capped_a100(rate: float):
    """An A100 whose profiled token rate is capped at ``rate`` — the same
    knob ``launch/serve.py --autoscale-node-rate`` uses so tiny smoke
    models don't look infinitely fast to the paper device profiles."""
    return dataclasses.replace(DEVICE_PROFILES["A100"],
                               max_tokens_per_s=rate)


def _traffic(rate_rps: float) -> TrafficProfile:
    return TrafficProfile(rate_rps=rate_rps,
                          buckets=[Bucket(EC.prompt_len, 6)], weights=[1.0])


# ---------------------------------------------------------------------------
# reweight_for_straggler unit tests (satellite: the dead export gets direct
# coverage in addition to its autoscaler caller)


def test_reweight_shifts_flow_away_placement_unchanged():
    """Degrading one of two identical full replicas must shift max-flow
    toward the healthy one: same placement, less flow through the victim,
    total throughput no higher than before."""
    model = small_model(8)
    cluster = make_cluster(["A100", "A100"])
    placement = Placement({"n0": LayerRange(0, 8), "n1": LayerRange(0, 8)},
                          8)
    p = plan(cluster, model, placement=placement)
    before = p.flows.get((COORDINATOR, "n1"), 0.0)
    assert before > 0, "healthy replica drew no flow"
    q = reweight_for_straggler(p, "n1", 0.2)
    after = q.flows.get((COORDINATOR, "n1"), 0.0)
    assert q.placement.assignment == p.placement.assignment
    assert after < before
    assert q.throughput <= p.throughput + 1e-9
    # the healthy replica's share does not shrink
    assert q.flows.get((COORDINATOR, "n0"), 0.0) >= \
        p.flows.get((COORDINATOR, "n0"), 0.0) - 1e-9


def test_reweight_rejects_unknown_node():
    model = small_model(8)
    cluster = make_cluster(2)
    placement = Placement({"n0": LayerRange(0, 8), "n1": LayerRange(0, 8)},
                          8)
    p = plan(cluster, model, placement=placement)
    with pytest.raises(KeyError):
        reweight_for_straggler(p, "nope", 0.5)


def test_straggler_reweight_applies_in_place(gqa_model, reference):
    """Fabricated decode telemetry shows n2 running 10x slower than the
    fleet median: the autoscaler reweights it (factor ~= median/slow),
    placement unchanged, SAME engine objects (update_weights in place, no
    rebuild) — and the runtime still serves byte-identical output."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 4), "n1": (0, 4), "n2": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    sc = Autoscaler(rt, p, traffic_fn=lambda: None, patience=1,
                    min_decode_tokens=1)
    rt.node_decode_s.update({"n0": 1.0, "n1": 1.0, "n2": 10.0})
    rt.node_decode_tokens.update({"n0": 100, "n1": 100, "n2": 100})
    engines_before = dict(rt.engines)
    sc.tick()
    assert sc._reweighted.get("n2") == pytest.approx(0.1)
    assert any(e.kind == "straggler" for e in sc.events)
    assert sc.plan.placement.assignment == p.placement.assignment
    rt.step()                      # the queued apply_plan lands here
    assert dict(rt.engines) == engines_before     # no rebuild, same objects
    reqs = [Request(i, pr, max_new_tokens=6)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    rt.run_until_done()
    assert [r.output for r in reqs] == ref
    assert_pools_drained(rt)
    # recovery: telemetry back to fleet speed restores full capacity
    rt.node_decode_s.update({"n0": 2.0, "n1": 2.0, "n2": 11.0})
    rt.node_decode_tokens.update({"n0": 200, "n1": 200, "n2": 200})
    sc.tick()
    assert "n2" not in sc._reweighted
    assert any("recovered" in e.detail for e in sc.events)


# ---------------------------------------------------------------------------
# scale-up under a load step (the acceptance-criteria live test)


def test_scale_up_under_load_step(gqa_model, reference):
    """Baseline traffic fits the 2-node fleet; a sustained 60 rps step does
    not (each capped node profiles at 400 tok/s).  After ``patience``
    overloaded ticks the autoscaler solves the mix, grows the cluster, and
    applies the plan between steps — requests already running keep their
    incumbent pipelines and finish byte-identical to the reference, and
    the grown fleet then serves through the new nodes too."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        transport=InProcessTransport(default_delay_s=1e-3))
    load = {"t": _traffic(25.0)}     # 550 tok/s: needs 2 nodes, fits 2
    sc = Autoscaler(rt, p, catalog={"A100": _capped_a100(400.0)},
                    patience=2, headroom=1.2, traffic_fn=lambda: load["t"])
    assert sc.tick() is None and sc.tick() is None   # steady state: no-op
    assert not sc.events

    reqs = [Request(i, pr, max_new_tokens=6)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    for _ in range(6):
        rt.step()                    # requests genuinely mid-flight
    assert rt.jobs, "nothing in flight before the load step"

    load["t"] = _traffic(60.0)       # 1320 tok/s: 2 x 400 cannot serve it
    assert sc.tick() is None         # patience: one hot tick buys nothing
    assert sc.tick() == "scale_up"
    assert any(e.kind == "scale_up" for e in sc.events)
    rt.step()                        # queued apply_plan lands between steps

    grown = set(rt.engines)
    assert {"n0", "n1"} < grown      # incumbents intact, new nodes added
    new_nodes = grown - {"n0", "n1"}
    assert new_nodes and all(n.startswith("a100-as") for n in new_nodes)
    for n in ("n0", "n1"):           # incumbent ranges untouched: no requeue
        assert rt.placement.assignment[n] == p.placement.assignment[n]
    assert rt.cluster.cost_per_hour() > p.cluster.cost_per_hour()

    rt.run_until_done()
    assert [r.output for r in reqs] == ref        # byte-identical through it
    assert_pools_drained(rt)
    extra = [Request(100 + i, pr, max_new_tokens=6)
             for i, pr in enumerate(prompts)]
    for r in extra:
        rt.submit(r)
    rt.run_until_done()
    assert [r.output for r in extra] == ref
    assert_pools_drained(rt)
    assert sc.describe()["num_events"] == len(sc.events)


def test_scale_up_respects_max_nodes(gqa_model):
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    sc = Autoscaler(rt, p, catalog={"A100": _capped_a100(400.0)},
                    patience=1, max_nodes=2,
                    traffic_fn=lambda: _traffic(60.0))
    assert sc.tick() is None         # would need 4 nodes > max_nodes=2
    assert any(e.kind == "error" and "max_nodes" in e.detail
               for e in sc.events)
    assert set(rt.cluster.nodes) - {COORDINATOR} == {"n0", "n1"}


# ---------------------------------------------------------------------------
# scale-down: two-phase drain + retire


def test_drain_then_retire_redundant_node(gqa_model, reference):
    """Three full replicas serving near-zero traffic: the autoscaler drains
    one (flow shifted away, placement unchanged) and retires it once the
    loop-thread probe confirms it holds no slots — survivors still serve
    byte-identical output at strictly lower $/hr."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 4), "n1": (0, 4), "n2": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    cost_before = rt.cluster.cost_per_hour()
    sc = Autoscaler(rt, p, catalog={"A100": _capped_a100(400.0)},
                    patience=1, traffic_fn=lambda: _traffic(2.0))
    assert sc.tick() == "drain"
    victim = sc.describe()["draining"]
    assert victim is not None
    rt.step()                        # reweight applies; busy probe runs
    assert sc.tick() == "retire"
    rt.step()                        # plan without the victim applies
    assert victim not in rt.engines
    assert victim not in rt.cluster.nodes
    assert rt.cluster.cost_per_hour() < cost_before
    kinds = [e.kind for e in sc.events]
    assert kinds.count("drain") == 1 and kinds.count("retire") == 1
    reqs = [Request(i, pr, max_new_tokens=6)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    rt.run_until_done()
    assert [r.output for r in reqs] == ref
    assert_pools_drained(rt)


def test_no_signal_means_no_action(gqa_model):
    """Without traffic signal the autoscaler must do nothing — an idle
    server is not an underloaded one (arrival stats may just be warming)."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    sc = Autoscaler(rt, p, patience=1, traffic_fn=lambda: None)
    for _ in range(3):
        assert sc.tick() is None
    assert not sc.events
    assert set(rt.cluster.nodes) - {COORDINATOR} == {"n0"}
