"""Cancel-on-disconnect: ``ClusterRuntime.cancel()`` must tear a request
down at ANY lifecycle point — still queued, mid-decode at pipeline depth
>= 2, with speculative verify windows in flight, or mid disaggregated
prefill->decode KV handoff — releasing KV/slots on EVERY stage node (pools
drain to zero, draft slots freed) while surviving requests stay
byte-identical to the single-engine reference.  Cancellation rides the
same ingest FIFO as ``submit`` (the front door calls it from HTTP handler
threads), so a cancel enqueued after its submit can never be reordered
before the job exists."""
import numpy as np

from repro.serving import ClusterRuntime, InProcessTransport, Request

from harness import (EC, assert_pools_drained, draft_model, make_disagg_plan,
                     make_plan, step_until)


def _submit_all(rt, prompts, max_new_tokens=6, **kw):
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        rt.submit(r, **kw)
    return reqs


def test_cancel_queued_request_before_prefill(gqa_model, reference):
    """Cancel landing while the request still sits in the admission queue:
    it finishes as "cancelled" with no output and no token of work done;
    everything else serves unchanged."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    reqs = _submit_all(rt, prompts)
    rt.cancel(reqs[1].request_id)     # same FIFO: drains after the submit
    rt.run_until_done()
    assert reqs[1].done and reqs[1].finish_reason == "cancelled"
    assert reqs[1].output == []
    assert [r.output for i, r in enumerate(reqs) if i != 1] == \
        [o for i, o in enumerate(ref) if i != 1]
    assert rt.cancelled_requests == 1
    assert_pools_drained(rt)


def test_cancel_mid_decode_depth2_three_stages(gqa_model, reference):
    """The headline case: a client vanishes mid-stream while its request is
    decoding across a 3-stage pipeline with an in-flight window.  The
    confirmed prefix is the greedy prefix, every stage node's pages drain,
    survivors are byte-identical, on_done fires exactly once with
    finish_reason="cancelled" — and the SAME runtime then serves a fresh
    request correctly (caches uncorrupted by the torn-down passes)."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        transport=InProcessTransport(default_delay_s=1e-3))
    done = []
    reqs = _submit_all(rt, prompts,
                       on_done=lambda rr: done.append(rr.request_id))
    # catch request 0 mid-decode with a speculative pass in flight
    step_until(rt, lambda rt: 0 in rt.jobs and len(reqs[0].output) >= 1
               and rt.jobs[0].inflight > 0)
    rt.cancel(0)
    rt.run_until_done()
    assert reqs[0].done and reqs[0].finish_reason == "cancelled"
    assert len(reqs[0].output) < len(ref[0])
    assert reqs[0].output == ref[0][:len(reqs[0].output)]
    assert [r.output for r in reqs[1:]] == ref[1:]
    assert rt.cancelled_requests == 1
    assert rt.cancelled_inflight > 0
    assert done.count(0) == 1
    assert sorted(done) == list(range(len(reqs)))
    assert_pools_drained(rt)
    extra = Request(99, prompts[0], max_new_tokens=6)
    rt.submit(extra)
    rt.run_until_done()
    assert extra.output == ref[0]
    assert_pools_drained(rt)


def test_cancel_with_spec_windows_inflight(gqa_model, reference):
    """Cancel while speculative verify rounds are in flight: the epoch bump
    kills the draft window, the coordinator draft slot is freed (checked by
    assert_pools_drained), and survivors still match the non-speculative
    reference byte-for-byte."""
    cfg, params = gqa_model
    prompts, ref = reference
    dcfg, dparams = draft_model(cfg, params)
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        draft_cfg=dcfg, draft_params=dparams, spec_tokens=3,
                        transport=InProcessTransport(default_delay_s=1e-3))
    reqs = _submit_all(rt, prompts)
    step_until(rt, lambda rt: 0 in rt.jobs and rt.jobs[0].inflight > 0)
    rt.cancel(0)
    rt.run_until_done()
    assert reqs[0].finish_reason == "cancelled"
    assert [r.output for r in reqs[1:]] == ref[1:]
    assert rt.spec_rounds > 0
    assert rt.cancelled_requests == 1
    assert_pools_drained(rt)          # page pools AND draft slots


def test_cancel_during_disagg_kv_handoff(gqa_model, reference):
    """Cancel while the prefill replica is still shipping KV to the decode
    replica (``kv_pending`` non-empty): the handoff is dropped on delivery,
    pages release on BOTH replicas, and the other requests decode to
    byte-identical outputs."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_disagg_plan(cfg, {"n0": (0, 4)}, {"n1": (0, 2), "n2": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        transport=InProcessTransport(default_delay_s=2e-3))
    reqs = _submit_all(rt, prompts)
    step_until(rt, lambda rt: any(j.kv_pending for j in rt.jobs.values()))
    victim = next(j for j in rt.jobs.values() if j.kv_pending)
    rid = victim.req.request_id
    rt.cancel(rid)
    rt.run_until_done()
    assert victim.req.finish_reason == "cancelled"
    assert [r.output for r in reqs if r.request_id != rid] == \
        [ref[r.request_id] for r in reqs if r.request_id != rid]
    assert rt.cancelled_requests == 1
    assert_pools_drained(rt)


def test_cancel_unknown_or_finished_is_noop(gqa_model, reference):
    """Cancelling an id that never existed, or one that already finished,
    changes nothing — no counter bump, no finish_reason rewrite."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    reqs = _submit_all(rt, prompts[:2])
    rt.run_until_done()
    assert [r.output for r in reqs] == ref[:2]
    rt.cancel(reqs[0].request_id)     # already finished
    rt.cancel(424242)                 # never seen
    rt.step()                         # drain the control messages
    assert rt.cancelled_requests == 0
    assert reqs[0].finish_reason != "cancelled"
    assert_pools_drained(rt)


def test_cancel_from_other_thread_while_serving(gqa_model, reference):
    """The real front-door shape: ``cancel`` called from another thread
    while the loop thread steps — lands through the ingest queue without
    corrupting the admission deque mid-iteration."""
    import threading

    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        transport=InProcessTransport(default_delay_s=1e-3))
    reqs = _submit_all(rt, prompts)
    step_until(rt, lambda rt: 0 in rt.jobs and len(reqs[0].output) >= 1)
    th = threading.Thread(target=rt.cancel, args=(0,))
    th.start()
    th.join()
    rt.run_until_done()
    assert reqs[0].finish_reason == "cancelled"
    assert [r.output for r in reqs[1:]] == ref[1:]
    assert rt.cancelled_requests == 1
    assert_pools_drained(rt)


def test_simulator_cancel_parity(gqa_model):
    """The event simulator's disconnect hook mirrors the runtime teardown:
    a cancelled request frees its KV + scheduler reservation, counts in
    ``cancelled_requests``, and the rest of the trace completes."""
    from repro.core import MILPOptions, plan
    from repro.sim import Simulator
    from repro.sim.traces import TraceRequest

    from harness import make_cluster, small_model

    model = small_model(8)
    cluster = make_cluster(["A100", "A100"])
    p = plan(cluster, model, MILPOptions(time_limit_s=5.0, lns_rounds=0,
                                         fgls_rounds=10))
    sim = Simulator(cluster, model, p.placement, p.make_scheduler(),
                    warmup_s=0.0, horizon_s=300.0, decode_chunk=4)
    trace = [TraceRequest(i, 0.05 * i, 64, 256) for i in range(6)]
    sim.cancel(1.0, 0)                # mid-decode for request 0
    sim.cancel(1.0, 999)              # unknown id: no-op
    m = sim.run(trace)
    assert m.cancelled_requests == 1
    assert m.completed_requests == len(trace) - 1
    assert m.dropped_requests == 0
