"""Differential test harness for the serving stack.

One set of builders for random heterogeneous clusters, placement-driven
plans, and request traces, shared by the runtime / paged-engine / scheduler
/ simulator tests (they used to carry copy-pasted variants).  On top of the
builders sit the differential assertions the pipelined-decode work hangs
off: a ``ClusterRuntime`` at ANY in-flight depth, dense or paged, must
produce greedy output byte-identical to a single full-model ``Engine``, and
every stage node's page pool must drain to zero afterwards.
"""
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs import get_smoke_config
from repro.core import (LayerRange, ModelProfile, Placement,
                        disaggregated_placement, full_mesh_cluster, plan)
from repro.core.cluster import ClusterSpec
from repro.serving import ClusterRuntime, Engine, EngineConfig, Request

# one engine shape shared by the runtime tests: small enough to be fast,
# big enough for preemption/budget scenarios
EC = EngineConfig(max_batch=4, max_len=48, prompt_len=16)


def f32(cfg):
    """float32 copy so paged (Pallas online-softmax) and dense (plain jnp)
    logits agree to argmax precision for greedy equivalence checks."""
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def draft_model(cfg, params=None, *, seed: int = 0):
    """A ``(draft_cfg, draft_params)`` pair for the speculation axis.
    ``seed=0`` re-inits the target's own architecture at the standard key —
    in these tests that reproduces the target's params exactly, giving a
    near-perfect-acceptance draft; any other seed gives a low-quality
    draft.  Either way greedy output must be byte-identical to the
    non-speculative reference — draft quality only changes speed."""
    if params is None:
        import jax

        from repro.models import init
        params = init(cfg, jax.random.key(seed))
    return cfg, params


# ---------------------------------------------------------------------------
# cluster / model / plan builders
# ---------------------------------------------------------------------------

def make_cluster(devs: Union[int, Sequence[str]], *,
                 inter_bw: float = 10e9 / 8,
                 latency_s: float = 1e-3) -> ClusterSpec:
    """Full-mesh single-region cluster.  ``devs`` is a device-name list
    (heterogeneous) or an int (that many A100s)."""
    return full_mesh_cluster(devs, bandwidth=inter_bw, latency_s=latency_s)


def small_model(num_layers: int = 8) -> ModelProfile:
    """Toy analytic model profile for scheduler/simulator tests."""
    return ModelProfile.from_dims("toy", num_layers=num_layers, d_model=4096,
                                  d_ff=11008, vocab=32000, n_kv_heads=32,
                                  head_dim=128)


def model_profile(cfg) -> ModelProfile:
    return ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)


def make_plan(cfg, assignment: Dict[str, Tuple[int, int]], *,
              devs: Optional[Sequence[str]] = None):
    """Plan for an explicit layer assignment ({node: (start, end)}) on a
    full-mesh cluster (A100s unless ``devs`` names heterogeneous devices)."""
    placement = Placement({n: LayerRange(*r) for n, r in assignment.items()},
                          cfg.num_layers)
    assert placement.validate() == []
    cluster = make_cluster(devs if devs is not None else len(assignment))
    return plan(cluster, model_profile(cfg), placement=placement)


def make_disagg_plan(cfg, prefill: Dict[str, Tuple[int, int]],
                     decode: Dict[str, Tuple[int, int]], *,
                     devs: Optional[Sequence[str]] = None):
    """Plan for a disaggregated placement: ``prefill`` and ``decode`` are
    each {node: (start, end)} groups covering the full model on their own
    (a node in both groups with the same range becomes ``mixed``)."""
    placement = disaggregated_placement(
        {n: LayerRange(*r) for n, r in prefill.items()},
        {n: LayerRange(*r) for n, r in decode.items()}, cfg.num_layers)
    n = len(placement.assignment)
    cluster = make_cluster(devs if devs is not None else n)
    return plan(cluster, model_profile(cfg), placement=placement)


def random_assignment(rng: np.random.RandomState, num_layers: int,
                      n_stages: int) -> Dict[str, Tuple[int, int]]:
    """Random contiguous abutting layer ranges over ``num_layers`` for
    ``n_stages`` nodes — a random heterogeneous pipeline shape."""
    assert 1 <= n_stages <= num_layers
    cuts = sorted(rng.choice(np.arange(1, num_layers), size=n_stages - 1,
                             replace=False).tolist())
    bounds = [0] + cuts + [num_layers]
    return {f"n{i}": (bounds[i], bounds[i + 1]) for i in range(n_stages)}


# ---------------------------------------------------------------------------
# traces + reference outputs
# ---------------------------------------------------------------------------

def random_prompts(cfg, lengths: Sequence[int], *,
                   seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=(int(n),)) for n in lengths]


def _as_requests(prompts, max_new_tokens) -> List[Request]:
    if isinstance(max_new_tokens, int):
        max_new_tokens = [max_new_tokens] * len(prompts)
    return [Request(i, p, max_new_tokens=int(m))
            for i, (p, m) in enumerate(zip(prompts, max_new_tokens))]


def reference_outputs(cfg, params, prompts, *, ec: EngineConfig = EC,
                      max_new_tokens=6, engine: Optional[Engine] = None
                      ) -> List[List[int]]:
    """Greedy outputs from a single full-model dense engine — the
    correctness anchor every cluster configuration must reproduce."""
    eng = engine if engine is not None else Engine(cfg, params, ec)
    reqs = _as_requests(prompts, max_new_tokens)
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(2000)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# ---------------------------------------------------------------------------
# differential serving
# ---------------------------------------------------------------------------

def serve_on_cluster(cfg, params, p, prompts, *, paged: bool,
                     max_inflight: int = 1, max_new_tokens=6,
                     ec: EngineConfig = EC, steps: Optional[int] = None,
                     **kw) -> Tuple[ClusterRuntime, List[Request]]:
    """Run ``prompts`` through a ClusterRuntime built from plan ``p``.
    ``steps`` runs a bounded number of iterations (for mid-flight fault
    injection) instead of to completion."""
    rt = ClusterRuntime(cfg, params, p, ec, paged=paged,
                        max_inflight=max_inflight, **kw)
    reqs = _as_requests(prompts, max_new_tokens)
    for r in reqs:
        rt.submit(r)
    if steps is None:
        rt.run_until_done()
        assert all(r.done for r in reqs)
    else:
        for _ in range(steps):
            rt.step()
    return rt, reqs


def step_until(rt: ClusterRuntime, pred, max_steps: int = 2000) -> None:
    """Step the runtime until ``pred(rt)`` holds — the hook the
    cancellation / autoscaler tests use to catch a request at a precise
    lifecycle point (mid-decode, mid KV handoff) before injecting."""
    for _ in range(max_steps):
        if pred(rt):
            return
        rt.step()
    raise AssertionError(f"predicate never held within {max_steps} steps")


def assert_pools_drained(rt: ClusterRuntime) -> None:
    """Every paged stage node must return to zero allocated pages — an
    in-flight token cancelled by eos/preemption/failover may never leak.
    When a draft model is attached its slots must all be free too: a
    speculative rollback or early eos may never strand a draft slot."""
    for node, used in rt.pool_pages_used().items():
        assert used == 0, f"{node} leaked {used} pages"
    if getattr(rt, "draft", None) is not None:
        free = rt.draft.free_slots
        assert free == rt.ec.max_batch, (
            f"draft engine leaked {rt.ec.max_batch - free} slots")


def assert_serves_like_reference(cfg, params, p, prompts, ref, *,
                                 paged: bool, max_inflight: int = 1,
                                 max_new_tokens=6, ec: EngineConfig = EC,
                                 spec: Optional[Tuple] = None,
                                 **kw) -> ClusterRuntime:
    """The differential anchor: byte-identical greedy output at any
    in-flight depth, pools drained on every node.  ``spec`` turns on
    speculative decoding: ``(draft_cfg, draft_params)`` or
    ``(draft_cfg, draft_params, spec_tokens)`` — greedy output must still
    match the non-speculative reference byte-for-byte."""
    if spec is not None:
        kw["draft_cfg"], kw["draft_params"] = spec[0], spec[1]
        if len(spec) > 2:
            kw["spec_tokens"] = spec[2]
    rt, reqs = serve_on_cluster(cfg, params, p, prompts, paged=paged,
                                max_inflight=max_inflight,
                                max_new_tokens=max_new_tokens, ec=ec, **kw)
    got = [r.output for r in reqs]
    assert got == ref, (f"depth={max_inflight} paged={paged} "
                        f"spec={spec is not None} diverged:\n"
                        f"  got {got}\n  ref {ref}")
    assert_pools_drained(rt)
    return rt


def pool_for_one_request(cfg, layers: LayerRange, *,
                         ec: EngineConfig = EC, page_size: int = 16) -> int:
    """Page count that fits exactly one full-budget request on a stage
    slice — the smallest legal pool, used to force preemption."""
    from repro.models.stage import stage_num_paged_layers
    n_paged = stage_num_paged_layers(cfg, layers)
    blocks = -(-ec.max_len // page_size)
    return 1 + blocks * n_paged
