"""Speculative decoding tests: a coordinator-side draft model proposing
gamma tokens per verify pass must leave greedy output BYTE-IDENTICAL to
non-speculative decoding for ANY draft quality — acceptance rate only
changes how many round-trips the output takes.  Covers the rollback paths
(param-dtype truncation, int8 page-snapshot restore), in-flight window
interaction, disaggregated placements, duplicate delivery, and the page
pool's truncate primitive the rollback is built on."""
import numpy as np
import pytest

from repro.core import LayerRange
from repro.serving import (ClusterRuntime, EngineConfig, InProcessTransport,
                           PagedStageEngine, Request)
from repro.serving.kv_pool import PagePool
from repro.serving.stage_engine import DecodeItem

from harness import (EC, assert_pools_drained, assert_serves_like_reference,
                     draft_model, make_disagg_plan, make_plan,
                     random_assignment, random_prompts, reference_outputs,
                     serve_on_cluster)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="session")
def bad_draft(gqa_model):
    """A draft with ~0% acceptance: same architecture, different init —
    the worst case for the rollback path, still byte-identical output."""
    cfg, _ = gqa_model
    return draft_model(cfg, seed=7)


# --- the correctness anchor: spec output == non-spec output -----------------

@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
@pytest.mark.parametrize("quality", ["perfect", "bad"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_spec_matches_reference(gqa_model, reference, bad_draft, paged,
                                quality, max_inflight):
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    spec = (cfg, params, 4) if quality == "perfect" else (*bad_draft, 4)
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=paged, max_inflight=max_inflight,
                                      spec=spec)
    assert rt.spec_rounds > 0 and rt.spec_proposed > 0
    if quality == "perfect":
        # identical params -> every draft accepted -> multi-token rounds
        assert rt.spec_rejected == 0
        assert rt.spec_tokens_per_round_trip > 1.5
    else:
        # every draft rejected -> degrades to one token per round-trip,
        # through the rollback path every single round
        assert rt.spec_accepted == 0
        assert rt.spec_rejected == rt.spec_proposed


def test_spec_three_stage_with_delay(gqa_model, reference, bad_draft):
    """3 uneven stages + modelled link delay + in-flight window: delivery
    timing must not let a stale (pre-rollback) pass confirm tokens."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    rt = assert_serves_like_reference(
        cfg, params, p, prompts, ref, paged=True, max_inflight=2,
        transport=InProcessTransport(default_delay_s=2e-3),
        spec=(*bad_draft, 3))
    assert rt.spec_rejected > 0


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_spec_disaggregated(gqa_model, reference, paged):
    """Prefill replica + decode replica: speculation runs on the decode
    pipeline; the KV handoff and the verify window must compose."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_disagg_plan(cfg, {"n0": (0, 4)}, {"n1": (0, 2), "n2": (2, 4)})
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=paged, spec=(cfg, params, 4))
    assert rt.spec_rounds > 0


def test_spec_int8_rollback_byte_identical(gqa_model, reference):
    """The hard case: int8 pages requantize the whole touched page per
    append, so a rejected sub-step would perturb KEPT rows' bytes unless
    rollback restores the pre-speculation page content.  The target is
    int8 while the draft runs float32, so their logits diverge and real
    rollbacks happen — output must still match a non-speculative int8 run
    byte-for-byte."""
    cfg, params = gqa_model
    prompts, _ = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt0, reqs0 = serve_on_cluster(cfg, params, p, prompts, paged=True,
                                  kv_dtype="int8")
    ref8 = [r.output for r in reqs0]
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref8,
                                      paged=True, kv_dtype="int8",
                                      spec=(cfg, params, 4))
    assert rt.spec_rejected > 0, \
        "int8 target vs f32 draft should reject at least once"


def test_spec_early_eos_mid_window(gqa_model):
    """max_new_tokens hit INSIDE the accepted prefix: the request completes
    from the partial window without a rollback, releasing slots (draft
    included) and pages everywhere."""
    cfg, params = gqa_model
    prompts = random_prompts(cfg, (10, 5, 16, 12), seed=0)
    lens = [1, 2, 3, 6]
    ref = reference_outputs(cfg, params, prompts, max_new_tokens=lens)
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    assert_serves_like_reference(cfg, params, p, prompts, ref, paged=True,
                                 max_new_tokens=lens, spec=(cfg, params, 4))


# --- rollback races: duplicates and stale in-flight work --------------------

class DuplicatingTransport(InProcessTransport):
    """Delivers every payload twice — work messages and verify results.
    The runtime's epoch-aware dedup keys must drop the copies; before the
    keys carried the epoch, a duplicate verify result raced the rollback
    and confirmed tokens from a cancelled window."""

    def send(self, src, dst, payload, nbytes, deliver):
        super().send(src, dst, payload, nbytes, deliver)
        super().send(src, dst, payload, nbytes, deliver)


def test_spec_duplicate_delivery_rollback_race(gqa_model, reference,
                                               bad_draft):
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = assert_serves_like_reference(
        cfg, params, p, prompts, ref, paged=True, max_inflight=2,
        transport=DuplicatingTransport(default_delay_s=1e-3),
        spec=(*bad_draft, 3))
    assert rt.spec_rejected > 0


# --- engine-level: int8 page snapshot restore -------------------------------

def test_int8_engine_rollback_restores_page_bytes(gqa_model):
    """Drive one PagedStageEngine directly: a rejected multi-token verify
    followed by rollback must leave the pool's int8 pages (content AND
    scales) byte-identical to an engine that only ever decoded the kept
    prefix — truncation alone fails this because rejected appends inflate
    the frontier page's absmax scale."""
    cfg, params = gqa_model
    ec = EngineConfig(max_batch=2, max_len=32, prompt_len=16)
    layers = LayerRange(0, cfg.num_layers)
    prompt = random_prompts(cfg, [6], seed=3)[0]
    a, b, x, y = 7, 11, 13, 17   # a,b kept; x,y rejected drafts

    def fresh(reserve):
        eng = PagedStageEngine(cfg, params, layers, ec, page_size=4,
                               kv_dtype="int8", rng_seed=0)
        slot = eng.alloc_slot(0)
        assert eng.ensure(slot, reserve)
        eng.prefill_chunk(slot, prompt, 0, 0)   # all-paged slice
        return eng, slot

    def slot_pages(eng, slot):
        pool = eng.pool
        nb = int(pool._nblocks[slot])
        pids = [int(pid) for pid in
                np.asarray(pool.table[:, slot, :nb]).reshape(-1)]
        return {pid: tuple(np.asarray(arr[pid]) for arr in
                           (pool.k, pool.v, pool.k_scales, pool.v_scales))
                for pid in pids}

    # reference history: decode exactly the kept tokens, one at a time,
    # reserving only what the kept prefix needs (rollback returns the
    # rejected window's pages, so allocations must match too)
    P = len(prompt)
    ref_eng, slot = fresh(P + 2)
    for s, tok in enumerate((a, b)):
        ref_eng.decode_stage([DecodeItem(slot=slot, pos=P + s, entry=0,
                                         token=tok)])
    want = slot_pages(ref_eng, slot)

    # speculative history: verify [a, b, x, y] in one call, reject x, y
    eng, slot2 = fresh(P + 4)
    assert slot2 == slot
    eng.decode_stage([DecodeItem(slot=slot2, pos=P, entry=0,
                                 tokens=[a, b, x, y])])
    eng.rollback(slot2, P + 2)
    got = slot_pages(eng, slot2)

    assert sorted(got) == sorted(want)
    for pid in want:
        for w, g in zip(want[pid], got[pid]):
            np.testing.assert_array_equal(w, g)


# --- pool primitive ---------------------------------------------------------

def test_pool_truncate_returns_pages(gqa_model):
    cfg, _ = gqa_model
    pool = PagePool(cfg, num_pages=64, page_size=4, max_batch=4,
                    max_seq_len=32, paged_layers=2)
    assert pool.ensure(0, 20)            # 5 blocks x 2 layers
    full = pool.used
    kept = {(li, bi): int(pool.table[li, 0, bi])
            for li in range(2) for bi in range(3)}
    pool.truncate(0, 9)                  # ceil(9/4) = 3 blocks
    assert pool.used == full - 2 * 2
    for (li, bi), pid in kept.items():   # kept blocks untouched
        assert int(pool.table[li, 0, bi]) == pid
    assert pool.ensure(0, 20)            # freed pages are reusable
    assert pool.used == full
    pool.truncate(0, 20)                 # no-op: target >= current
    assert pool.used == full
    pool.release(0)
    assert pool.used == 0


# --- property: spec == non-spec for random configurations -------------------

def _assert_spec_equals_nonspec(gqa_model, bad_draft, seed: int) -> None:
    cfg, params = gqa_model
    rng = np.random.RandomState(seed)
    p = make_plan(cfg, random_assignment(rng, cfg.num_layers,
                                         int(rng.randint(1, 4))))
    paged = bool(rng.randint(2))
    kv_dtype = "int8" if paged and rng.randint(2) else None
    depth = int(rng.randint(1, 3))
    draft = (cfg, params) if rng.randint(2) else bad_draft
    gamma = int(rng.randint(1, 6))
    new_tokens = int(rng.randint(1, 8))
    prompts = random_prompts(cfg, rng.randint(2, 16, size=3), seed=seed)
    _, reqs = serve_on_cluster(cfg, params, p, prompts, paged=paged,
                               kv_dtype=kv_dtype,
                               max_new_tokens=new_tokens)
    ref = [r.output for r in reqs]
    assert_serves_like_reference(cfg, params, p, prompts, ref, paged=paged,
                                 kv_dtype=kv_dtype, max_inflight=depth,
                                 max_new_tokens=new_tokens,
                                 spec=(*draft, gamma))


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_spec_property(gqa_model, bad_draft, seed):
        _assert_spec_equals_nonspec(gqa_model, bad_draft, seed)
else:
    @pytest.mark.parametrize("seed", [11, 29])
    def test_spec_property_seeded(gqa_model, bad_draft, seed):
        _assert_spec_equals_nonspec(gqa_model, bad_draft, seed)
