"""IWRR per-request pipeline scheduler tests (+ hypothesis properties)."""
import collections

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # only the property test skips
    HAVE_HYPOTHESIS = False

from repro.core import (COORDINATOR, IWRR, KVEstimator, LayerRange,
                        MILPOptions, Placement, RandomScheduler,
                        RequestPipeline, SwarmScheduler, plan)

from harness import make_cluster, small_model


# --- IWRR properties ---------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=0.5, max_value=50.0), min_size=1,
                    max_size=6))
    def test_iwrr_frequencies_proportional_to_weights(weights):
        cands = [f"c{i}" for i in range(len(weights))]
        iwrr = IWRR(cands, weights)
        n = 5000
        counts = collections.Counter(iwrr.pick() for _ in range(n))
        total_w = sum(weights)
        for c, w in zip(cands, weights):
            expected = n * w / total_w
            # IWRR is deterministic: counts within 1 period of expected
            assert abs(counts[c] - expected) <= total_w / min(weights) + 2


def test_iwrr_no_bursts_for_equal_weights():
    iwrr = IWRR(["a", "b"], [1.0, 1.0])
    seq = [iwrr.pick() for _ in range(10)]
    for x, y in zip(seq, seq[1:]):
        assert x != y, f"burst in {seq}"


def test_iwrr_respects_mask():
    iwrr = IWRR(["a", "b"], [1.0, 1.0])
    for _ in range(5):
        assert iwrr.pick(masked={"a"}) == "b"


def test_iwrr_all_masked_returns_none():
    iwrr = IWRR(["a"], [1.0])
    assert iwrr.pick(masked={"a"}) is None


# --- pipeline construction ---------------------------------------------------

def _plan(devs, layers):
    cluster = make_cluster(devs)
    model = small_model(layers)
    return plan(cluster, model, MILPOptions(time_limit_s=15.0, lns_rounds=0))


def test_helix_pipelines_always_valid():
    p = _plan(("A100", "L4", "T4", "T4"), 8)
    sched = p.make_scheduler()
    for _ in range(200):
        pipe = sched.schedule(prompt_tokens=128)
        assert pipe.validate(p.model.num_layers) == []
        sched.finish(pipe, 128)


def test_swarm_and_random_pipelines_valid():
    p = _plan(("A100", "L4", "T4", "T4"), 8)
    for cls in (SwarmScheduler, RandomScheduler):
        sched = cls(p.cluster, p.model, p.placement)
        for _ in range(100):
            pipe = sched.schedule()
            assert pipe.validate(p.model.num_layers) == []


def test_helix_respects_flow_proportions():
    """Node usage frequency across many requests approximates edge flows."""
    p = _plan(("A100", "T4", "T4", "T4"), 4)
    sched = p.make_scheduler(with_kv_estimation=False)
    counts = collections.Counter()
    n = 2000
    for _ in range(n):
        pipe = sched.schedule()
        for st_ in pipe.stages:
            counts[st_.node] += 1
    # first-hop flow fractions
    first_flows = {v: f for (u, v), f in p.flows.items() if u == COORDINATOR}
    total = sum(first_flows.values())
    for node, f in first_flows.items():
        # node appears at least as often as its first-hop share
        assert counts[node] >= 0.8 * n * f / total - 5


def test_kv_masking_blocks_saturated_node():
    p = _plan(("A100", "A100"), 4)
    sched = p.make_scheduler()
    # saturate n0's KV estimate
    cap = sched.kv.capacity_tokens["n0"]
    sched.kv.reserve("n0", cap)
    for _ in range(20):
        pipe = sched.schedule()
        assert "n0" not in pipe.nodes


def test_kv_release_restores_node():
    p = _plan(("A100", "A100"), 4)
    sched = p.make_scheduler()
    cap = sched.kv.capacity_tokens["n0"]
    sched.kv.reserve("n0", cap)
    sched.kv.release("n0", cap)
    seen = set()
    for _ in range(50):
        seen.update(sched.schedule().nodes)
    assert "n0" in seen


def test_masked_pipelines_layer_ranges_abut():
    """Regression: pipelines built while nodes are KV-masked — including
    *fallback* picks, where every flow-positive candidate is masked and the
    scheduler falls back to the least-loaded valid node — must still produce
    stages whose layer ranges abut exactly (RequestPipeline.validate)."""
    cluster = make_cluster(("A100", "A100", "A100"))
    model = small_model(8)
    placement = Placement({"n0": LayerRange(0, 4), "n1": LayerRange(4, 8),
                           "n2": LayerRange(4, 8)}, 8)
    p = plan(cluster, model, placement=placement)
    sched = p.make_scheduler()
    # route all flow through n1 so n2 is never a flow candidate ...
    sched.update_weights({(COORDINATOR, "n0"): 1.0, ("n0", "n1"): 1.0,
                          ("n1", COORDINATOR): 1.0})
    # ... then mask n1: the n0 hop must FALL BACK to n2 (zero flow), and the
    # resulting pipeline must still cover [0,8) with abutting stages
    sched.kv.reserve("n1", sched.kv.capacity_tokens["n1"])
    for _ in range(50):
        pipe = sched.schedule(prompt_tokens=16)
        assert isinstance(pipe, RequestPipeline)
        assert pipe.validate(model.num_layers) == []
        assert "n1" not in pipe.nodes and "n2" in pipe.nodes
        for a, b in zip(pipe.stages, pipe.stages[1:]):
            assert a.layers.end == b.layers.start
        sched.finish(pipe, 16)


def test_kv_sync_overrides_reservation_drift():
    """KVEstimator.sync installs measured occupancy verbatim — the §4.2 mask
    then follows reality, not the accumulated reserve/release estimate."""
    kv = KVEstimator(capacity_tokens={"n0": 100.0})
    kv.reserve("n0", 95.0)              # stale reservation: node looks full
    assert "n0" in kv.masked_nodes()
    kv.sync("n0", 10.0)                 # true pool occupancy is tiny
    assert "n0" not in kv.masked_nodes()
    kv.sync("n0", 95.0)
    assert "n0" in kv.masked_nodes()


def test_update_weights_swaps_routing():
    p = _plan(("A100", "A100"), 4)
    sched = p.make_scheduler(with_kv_estimation=False)
    # zero out flow to n1: all requests go through n0
    flows = {k: (0.0 if "n1" in k else v) for k, v in p.flows.items()}
    flows[(COORDINATOR, "n0")] = 1.0
    flows[("n0", COORDINATOR)] = 1.0
    sched.update_weights(flows)
    for _ in range(20):
        assert sched.schedule().nodes == ("n0",)
