"""ClusterRuntime tests: multi-stage pipelines over per-node stage engines
must serve token-for-token identically to a single full-model engine (the
correctness anchor for the cross-node execution layer) at EVERY in-flight
decode depth, pools must drain on completion on every stage node, and
preemption / transport delays / partial inference / failover / eos arriving
mid-window must not change outputs or leak pages.  Builders and the
differential assertions live in tests/harness.py."""
import dataclasses

import numpy as np
import pytest

from repro.core import (COORDINATOR, LayerRange, MILPOptions,
                        replan_after_failure)
from repro.models.stage import stage_num_paged_layers
from repro.serving import (ClusterRuntime, Engine, EngineConfig,
                           InProcessTransport, PagedStageEngine, Request)

from harness import (EC, assert_pools_drained, assert_serves_like_reference,
                     f32, make_disagg_plan, make_plan, pool_for_one_request,
                     random_assignment, random_prompts, reference_outputs,
                     serve_on_cluster)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # only the property test skips
    HAVE_HYPOTHESIS = False


# --- greedy equivalence: the correctness anchor ------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_two_stage_matches_single_engine(gqa_model, reference, paged):
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=paged)
    # each engine holds only its slice
    assert [len(e.sparams["blocks"]) for _, e in sorted(rt.engines.items())] \
        == [2, 2]
    for i in range(len(prompts)):
        assert len(rt.served[i].stages) == 2


@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_three_stage_matches_single_engine(gqa_model, reference, paged,
                                           max_inflight):
    """3 uneven stages, with a modelled per-link transport delay — neither
    the extra hop, delivery timing, nor a pipelined in-flight window may
    change a single token."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    rt = assert_serves_like_reference(
        cfg, params, p, prompts, ref, paged=paged, max_inflight=max_inflight,
        transport=InProcessTransport(default_delay_s=2e-3))
    for i in range(len(prompts)):
        assert len(rt.served[i].stages) == 3
    assert rt._now > 0.0          # the virtual clock actually advanced


def test_inflight_depth2_reduces_decode_latency(gqa_model, reference):
    """The acceptance bar for pipelined decode: on a 3-stage placement with
    per-link delay d > 0, depth 2 launches pass t+1 from the final stage
    (1 hop to stage 0) instead of round-tripping through the coordinator
    (2 hops) — per-token decode latency must drop from (k+1)d to k*d while
    output stays byte-identical to the single full-model engine."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    d = 2e-3
    lat = {}
    for depth in (1, 2):
        rt = assert_serves_like_reference(
            cfg, params, p, prompts, ref, paged=True, max_inflight=depth,
            transport=InProcessTransport(default_delay_s=d))
        lat[depth] = rt.mean_decode_latency()
    assert lat[1] == pytest.approx(4 * d)      # final->coord->s0 + 2 hops
    assert lat[2] == pytest.approx(3 * d)      # final->s0 + 2 hops
    assert lat[2] < 0.8 * lat[1]


def test_partial_inference_entry_mid_node(gqa_model, reference):
    """Replicated placement: a request reaching a node that holds [0, 4) at
    layer 2 must infer only [2, 4) there (§3.3) — outputs unchanged, also
    with an in-flight window."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (0, 4), "n2": (2, 4)})
    # pin the flows so every request routes n0 -> n1: n1 holds [0, 4) but
    # must start inferring at layer 2 (max-flow might otherwise avoid the
    # replicated path entirely)
    p = dataclasses.replace(p, flows={(COORDINATOR, "n0"): 1.0,
                                      ("n0", "n1"): 1.0,
                                      ("n1", COORDINATOR): 1.0})
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=True, max_inflight=2)
    mid_entry = any(
        st_.layers.start > rt.placement.assignment[st_.node].start
        for pipe in rt.served.values() for st_ in pipe.stages)
    assert mid_entry, "no pipeline exercised a mid-node entry"


@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
def test_pool_exhaustion_preempts_pipeline_wide(gqa_model, reference,
                                                max_inflight):
    """A mid-stage pool that fits one full-budget request forces preemption
    — with depth 2 that includes cancelling speculative in-flight tokens;
    recompute-on-readmit must keep outputs identical and drain every pool."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    small = pool_for_one_request(cfg, LayerRange(2, 3))
    rt, reqs = serve_on_cluster(cfg, params, p, prompts, paged=True,
                                max_inflight=max_inflight,
                                pool_pages={"n1": small})
    assert [r.output for r in reqs] == ref
    assert any(r.preemptions > 0 for r in reqs)
    assert_pools_drained(rt)


def test_hybrid_stack_multi_stage_paged(gqa_model):
    """Hybrid (mamba/MoE + GQA) slices: paged attention + dense fallback
    split across stages still matches the full dense engine at depth 2.
    n0's slice holds *no* paged block at all (jamba's attn blocks sit at
    layers 3 and 7) — the runtime must give it a dense stage engine even in
    paged mode instead of crashing at construction."""
    from repro.configs import get_smoke_config
    from repro.models import init
    import jax
    cfg = f32(get_smoke_config("jamba_1_5_large_398b"))
    params = init(cfg, jax.random.key(2))
    assert stage_num_paged_layers(cfg, LayerRange(0, 3)) == 0
    prompts = random_prompts(cfg, (11,), seed=1)
    ec = EngineConfig(max_batch=2, max_len=48, prompt_len=16)
    ref = reference_outputs(cfg, params, prompts, ec=ec, max_new_tokens=6)
    p = make_plan(cfg, {"n0": (0, 3), "n1": (3, 5), "n2": (5, 8)})
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=True, max_inflight=2, ec=ec)
    assert not isinstance(rt.engines["n0"], PagedStageEngine)
    assert isinstance(rt.engines["n1"], PagedStageEngine)


# --- routed forwarding: hop accounting ---------------------------------------

def test_direct_links_reduce_decode_hops(gqa_model, reference):
    """The tentpole's measurable claim: on a k=3 stage pipeline with
    per-link delay d, star routing charges 2k hops per decode token (every
    stage output bounces through the coordinator) while direct links charge
    k+1 (k-1 peer hops + the token's coordinator round trip) — and the
    per-token latency drops accordingly.  Counters come from the
    transport's per-(src,dst) ledger, which also feeds describe()."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    d = 2e-3
    hops, lat = {}, {}
    for direct in (False, True):
        tr = InProcessTransport(default_delay_s=d, direct_links=direct)
        rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                          paged=True, transport=tr)
        n_tokens = sum(len(r) for r in ref)
        hops[direct] = sum(tr.transfers.values()) / n_tokens
        lat[direct] = rt.mean_decode_latency()
        peer = {k: v for k, v in tr.transfers.items()
                if COORDINATOR not in k}
        if direct:
            assert peer.get(("n0", "n1")) and peer.get(("n1", "n2")), peer
        else:
            assert not peer, f"star mode must not use peer links: {peer}"
        assert "hops[" in tr.describe()
    assert hops[False] == pytest.approx(6.0)       # 2k
    assert hops[True] == pytest.approx(4.0)        # k+1
    assert lat[False] == pytest.approx(6 * d)
    assert lat[True] == pytest.approx(4 * d)


# --- disaggregated prefill/decode --------------------------------------------

@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_disaggregated_matches_single_engine(gqa_model, reference, paged,
                                             max_inflight):
    """One prefill replica holding the full model, a 2-stage decode
    replica: prompts run on n0, the filled KV ships over peer links to
    n1/n2, decode runs only there — outputs byte-identical to the single
    full-model engine, pools drained everywhere."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_disagg_plan(cfg, {"n0": (0, 4)}, {"n1": (0, 2), "n2": (2, 4)})
    tr = InProcessTransport(default_delay_s=1e-3)
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=paged, max_inflight=max_inflight,
                                      transport=tr)
    assert rt.disaggregated
    # every request's KV actually travelled prefill -> decode
    assert tr.transfers[("n0", "n1")] >= len(prompts)
    assert tr.transfers[("n0", "n2")] >= len(prompts)
    # decode stage-work only ever ran on the decode replica
    for pipe in rt.served.values():
        assert {st.node for st in pipe.stages} <= {"n1", "n2"}


def test_disaggregated_mixed_node_keeps_kv_home(gqa_model, reference):
    """A node in both groups (``mixed``) decodes from the KV its own
    prefill pass filled: no handoff is shipped for its layers."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_disagg_plan(cfg, {"n0": (0, 2), "n1": (2, 4)},
                         {"n2": (0, 2), "n1": (2, 4)})
    assert p.placement.meta["roles"] == {"n0": "prefill", "n1": "mixed",
                                         "n2": "decode"}
    tr = InProcessTransport(default_delay_s=1e-3)
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=True, max_inflight=2,
                                      transport=tr)
    assert tr.transfers[("n0", "n2")] >= len(prompts)   # layers [0, 2) ship
    # n1's KV stays home: its outgoing peer traffic is speculative-launch
    # tokens only (token_bytes each), never a KV payload
    assert tr.bytes_sent[("n1", "n2")] == \
        tr.transfers[("n1", "n2")] * rt.profile.token_bytes
    assert rt.disaggregated


def test_disaggregated_failover_replans_to_mixed(gqa_model, reference):
    """Kill a decode-replica node mid-flight: in-flight requests requeue,
    the generic replan returns a role-less placement (disaggregation is
    dropped, not wedged), and outputs still match the reference."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_disagg_plan(cfg, {"n0": (0, 4)},
                         {"n1": (0, 2), "n2": (2, 4), "n3": (0, 4)})
    rt, reqs = serve_on_cluster(cfg, params, p, prompts, paged=True,
                                max_inflight=2, steps=8,
                                transport=InProcessTransport(
                                    default_delay_s=1e-3))
    assert rt.jobs, "nothing in flight before the failure"
    rt.fail_node("n1")
    new = replan_after_failure(p, "n1", MILPOptions(time_limit_s=5.0,
                                                    lns_rounds=0,
                                                    fgls_rounds=10))
    rt.apply_plan(new)
    rt.run_until_done()
    assert [r.output for r in reqs] == ref
    assert "n1" not in rt.engines
    assert_pools_drained(rt)


# --- property: any placement x depth x trace ---------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(data=st.data())
    def test_property_any_depth_matches_single_engine(gqa_model, data):
        """Random stage count / layer cuts / in-flight depth / trace: the
        runtime's greedy output is identical to single-engine decode and
        every pool drains to zero."""
        cfg, params = gqa_model
        n_stages = data.draw(st.integers(1, 3), label="n_stages")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        depth = data.draw(st.integers(1, 3), label="max_inflight")
        lengths = data.draw(st.lists(st.integers(1, 16), min_size=2,
                                     max_size=3), label="prompt_lengths")
        max_new = data.draw(st.lists(st.integers(1, 8),
                                     min_size=len(lengths),
                                     max_size=len(lengths)),
                            label="max_new_tokens")
        rng = np.random.RandomState(seed)
        assignment = random_assignment(rng, cfg.num_layers, n_stages)
        prompts = random_prompts(cfg, lengths, seed=seed)
        ref = reference_outputs(cfg, params, prompts, ec=EC,
                                max_new_tokens=max_new)
        p = make_plan(cfg, assignment)
        assert_serves_like_reference(cfg, params, p, prompts, ref,
                                     paged=True, max_inflight=depth,
                                     max_new_tokens=max_new)


# --- scheduler feedback ------------------------------------------------------

def test_kv_estimator_sees_true_pool_occupancy(gqa_model):
    """The runtime must report real PagePool usage (and capacity) into the
    scheduler's KVEstimator — not arrival-time reservations."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    kv = rt.scheduler.kv
    for node, eng in rt.engines.items():
        assert kv.capacity_tokens[node] == eng.pool.tokens_capacity
    rt.submit(Request(0, np.arange(10) % cfg.vocab_size, max_new_tokens=8))
    for _ in range(4):
        rt.step()
    assert any(eng.pool.tokens_used > 0 for eng in rt.engines.values())
    for node, eng in rt.engines.items():
        assert kv.usage[node] == eng.pool.tokens_used
    rt.run_until_done()
    for node in rt.engines:
        assert kv.usage[node] == 0


# --- fault injection on the in-flight window ---------------------------------

def test_eos_mid_window_cancels_inflight_cleanly(gqa_model, reference):
    """eos confirmed at the coordinator while the speculative pass for
    token t+1 is still mid-pipeline: the pass must be cancelled (epoch),
    no page may leak, and the truncated output must equal the reference cut
    at eos — then the SAME runtime must serve a fresh request correctly
    (caches uncorrupted by the cancelled write)."""
    cfg, params = gqa_model
    prompts, ref = reference
    # make the token greedy decode emits mid-stream (index 2 of request 0)
    # the eos token; requests whose outputs contain it stop there
    eos = ref[0][2]
    ec = dataclasses.replace(EC, eos_token=eos)

    def cut(out):
        return out[:out.index(eos) + 1] if eos in out else out

    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    rt, reqs = serve_on_cluster(
        cfg, params, p, prompts, paged=True, max_inflight=3, ec=ec,
        transport=InProcessTransport(default_delay_s=1e-3))
    assert [r.output for r in reqs] == [cut(o) for o in ref]
    assert reqs[0].finish_reason == "stop"
    assert rt.cancelled_inflight > 0, \
        "no speculative pass was in flight when eos confirmed"
    assert_pools_drained(rt)
    # the runtime keeps serving correctly after the cancellations
    extra = Request(99, prompts[1], max_new_tokens=6)
    rt.submit(extra)
    rt.run_until_done()
    assert extra.output == cut(ref[1])
    assert_pools_drained(rt)


class _ReorderingTransport(InProcessTransport):
    """The first delivery to the coordinator is slower than later ones, so
    a speculative pass's token (output index 1) overtakes prefill's token
    (index 0) on the return path — legal under the base Transport contract
    ('send must eventually deliver'), never produced by the FIFO
    InProcessTransport."""

    def __init__(self):
        super().__init__(default_delay_s=1e-3)
        self._slowed = set()

    def delay(self, src, dst, nbytes):
        d = super().delay(src, dst, nbytes)
        if dst == COORDINATOR and src not in self._slowed:
            self._slowed.add(src)
            return d + 5e-3
        return d


def test_out_of_order_token_arrival_confirms_in_order(gqa_model, reference):
    """Decode tokens reaching the coordinator before the prefill token must
    wait in the inbox and confirm in output order once it lands — not
    strand the request (regression: _on_first_token used to skip the inbox
    drain)."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    assert_serves_like_reference(cfg, params, p, prompts, ref, paged=False,
                                 max_inflight=2,
                                 transport=_ReorderingTransport())


def test_failover_replan_re_prefills_in_flight(gqa_model, reference):
    """Kill a stage node mid-decode with an active in-flight window: the
    speculative passes die with the epoch bump, survivors release the
    victims' KV, the replanned placement is adopted, in-flight requests
    re-prefill (keeping generated tokens) and finish with unchanged
    outputs."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4), "n2": (0, 4)})
    rt, reqs = serve_on_cluster(cfg, params, p, prompts, paged=True,
                                max_inflight=2, steps=6)
    assert rt.jobs, "nothing in flight before the failure"
    rt.fail_node("n1")
    new = replan_after_failure(p, "n1", MILPOptions(time_limit_s=5.0,
                                                    lns_rounds=0,
                                                    fgls_rounds=10))
    rt.apply_plan(new)
    rt.run_until_done()
    assert [r.output for r in reqs] == ref
    assert "n1" not in rt.engines
    assert_pools_drained(rt)


# --- guards ------------------------------------------------------------------

def test_runtime_rejects_oversized_prompt(gqa_model):
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    with pytest.raises(ValueError, match="truncate"):
        rt.submit(Request(0, np.arange(EC.max_len + 1) % cfg.vocab_size))
    with pytest.raises(ValueError, match="empty"):
        rt.submit(Request(1, np.zeros((0,), np.int32)))
    with pytest.raises(ValueError, match="max_inflight"):
        ClusterRuntime(cfg, params, p, EC, paged=False, max_inflight=0)


def test_run_until_done_exhaustion_raises_with_diagnostics(gqa_model):
    """Regression: exhausting max_iters must raise with queue/in-flight
    diagnostics, never return silently with requests outstanding — for the
    ClusterRuntime AND the single-node engines."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    rt.submit(Request(0, np.arange(10) % cfg.vocab_size, max_new_tokens=8))
    with pytest.raises(RuntimeError, match=r"not done after 2.*queued="):
        rt.run_until_done(max_iters=2)
    eng = Engine(cfg, params, EC)
    eng.submit(Request(0, np.arange(10) % cfg.vocab_size, max_new_tokens=8))
    with pytest.raises(RuntimeError, match=r"not done after 1.*active=1"):
        eng.run_until_done(max_iters=1)
    # fencepost: finishing exactly on the last allowed iteration is success
    eng2 = Engine(cfg, params, EC)
    done_in_one = Request(1, np.arange(10) % cfg.vocab_size,
                          max_new_tokens=1)
    eng2.submit(done_in_one)
    eng2.run_until_done(max_iters=1)
    assert done_in_one.done


def test_stage_engine_holds_only_its_slice(gqa_model):
    cfg, params = gqa_model
    eng = PagedStageEngine(cfg, params, LayerRange(1, 3), EC)
    assert len(eng.sparams["blocks"]) == 2
    assert "embed" not in eng.sparams       # neither first nor last stage
    assert "final_norm" not in eng.sparams
    assert eng.pool.num_layers == 2         # pool priced at *local* layers
