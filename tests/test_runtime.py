"""ClusterRuntime tests: multi-stage pipelines over per-node stage engines
must serve token-for-token identically to a single full-model engine (the
correctness anchor for the cross-node execution layer), pools must drain on
completion on every stage node, and preemption / transport delays / partial
inference / failover must not change outputs."""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core import (COORDINATOR, LayerRange, MILPOptions, ModelProfile,
                        Placement, plan, replan_after_failure)
from repro.core.cluster import DEVICE_PROFILES, ClusterSpec, NodeSpec
from repro.core.cluster import _full_mesh_links
from repro.models import init
from repro.models.stage import stage_num_paged_layers
from repro.serving import (ClusterRuntime, Engine, EngineConfig,
                           InProcessTransport, PagedStageEngine, Request)


def f32(cfg):
    """float32 so paged (Pallas online-softmax) and dense logits agree to
    argmax precision for greedy equivalence."""
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def make_cluster(n):
    nodes, regions = {}, {COORDINATOR: "r0"}
    for i in range(n):
        nodes[f"n{i}"] = NodeSpec(f"n{i}", DEVICE_PROFILES["A100"],
                                  region="r0")
        regions[f"n{i}"] = "r0"
    links = _full_mesh_links(list(nodes), regions, 10e9 / 8, 1e-3,
                             10e9 / 8, 1e-3)
    return ClusterSpec(nodes=nodes, links=links)


def make_plan(cfg, assignment):
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    placement = Placement({n: LayerRange(*r) for n, r in assignment.items()},
                          cfg.num_layers)
    assert placement.validate() == []
    return plan(make_cluster(len(assignment)), profile, placement=placement)


EC = EngineConfig(max_batch=4, max_len=48, prompt_len=16)


@pytest.fixture(scope="module")
def gqa_model():
    cfg = f32(get_smoke_config("smollm_360m"))
    return cfg, init(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def reference(gqa_model):
    """Prompts + greedy outputs from a single full-model dense engine."""
    cfg, params = gqa_model
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=(n,))
               for n in (10, 5, 16, 12)]
    eng = Engine(cfg, params, EC)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(300)
    assert all(r.done for r in reqs)
    return prompts, [r.output for r in reqs]


def serve(cfg, params, p, prompts, *, paged, new_tokens=6, **kw):
    rt = ClusterRuntime(cfg, params, p, EC, paged=paged, **kw)
    reqs = [Request(i, pr, max_new_tokens=new_tokens)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    rt.run_until_done()
    assert all(r.done for r in reqs)
    return rt, reqs


# --- greedy equivalence: the correctness anchor ------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_two_stage_matches_single_engine(gqa_model, reference, paged):
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt, reqs = serve(cfg, params, p, prompts, paged=paged)
    assert [r.output for r in reqs] == ref
    # each engine holds only its slice
    assert [len(e.sparams["blocks"]) for _, e in sorted(rt.engines.items())] \
        == [2, 2]
    for i in range(len(prompts)):
        assert len(rt.served[i].stages) == 2
    if paged:
        # pool drains to zero on every stage node after completion
        assert all(v == 0 for v in rt.pool_pages_used().values())


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_three_stage_matches_single_engine(gqa_model, reference, paged):
    """3 uneven stages, with a modelled per-link transport delay — neither
    the extra hop nor delivery timing may change a single token."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    rt, reqs = serve(cfg, params, p, prompts, paged=paged,
                     transport=InProcessTransport(default_delay_s=2e-3))
    assert [r.output for r in reqs] == ref
    for i in range(len(prompts)):
        assert len(rt.served[i].stages) == 3
    if paged:
        assert all(v == 0 for v in rt.pool_pages_used().values())
    assert rt._now > 0.0          # the virtual clock actually advanced


def test_partial_inference_entry_mid_node(gqa_model, reference):
    """Replicated placement: a request reaching a node that holds [0, 4) at
    layer 2 must infer only [2, 4) there (§3.3) — outputs unchanged."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (0, 4), "n2": (2, 4)})
    # pin the flows so every request routes n0 -> n1: n1 holds [0, 4) but
    # must start inferring at layer 2 (max-flow might otherwise avoid the
    # replicated path entirely)
    p = dataclasses.replace(p, flows={(COORDINATOR, "n0"): 1.0,
                                      ("n0", "n1"): 1.0,
                                      ("n1", COORDINATOR): 1.0})
    rt, reqs = serve(cfg, params, p, prompts, paged=True)
    assert [r.output for r in reqs] == ref
    mid_entry = any(
        st.layers.start > rt.placement.assignment[st.node].start
        for pipe in rt.served.values() for st in pipe.stages)
    assert mid_entry, "no pipeline exercised a mid-node entry"
    assert all(v == 0 for v in rt.pool_pages_used().values())


def test_pool_exhaustion_preempts_pipeline_wide(gqa_model, reference):
    """A mid-stage pool that fits one full-budget request forces preemption;
    recompute-on-readmit must keep outputs identical and drain every pool."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    n_paged = stage_num_paged_layers(cfg, LayerRange(2, 3))
    small = 1 + (EC.max_len // 16) * n_paged
    rt, reqs = serve(cfg, params, p, prompts, paged=True,
                     pool_pages={"n1": small})
    assert [r.output for r in reqs] == ref
    assert any(r.preemptions > 0 for r in reqs)
    assert all(v == 0 for v in rt.pool_pages_used().values())


def test_hybrid_stack_multi_stage_paged(gqa_model):
    """Hybrid (mamba/MoE + GQA) slices: paged attention + dense fallback
    split across stages still matches the full dense engine.  n0's slice
    holds *no* paged block at all (jamba's attn blocks sit at layers 3 and
    7) — the runtime must give it a dense stage engine even in paged mode
    instead of crashing at construction."""
    cfg = f32(get_smoke_config("jamba_1_5_large_398b"))
    params = init(cfg, jax.random.key(2))
    assert stage_num_paged_layers(cfg, LayerRange(0, 3)) == 0
    prompt = np.random.RandomState(1).randint(0, cfg.vocab_size, size=(11,))
    ec = EngineConfig(max_batch=2, max_len=48, prompt_len=16)
    ref_eng = Engine(cfg, params, ec)
    r1 = Request(0, prompt, max_new_tokens=6)
    ref_eng.submit(r1)
    ref_eng.run_until_done(50)
    p = make_plan(cfg, {"n0": (0, 3), "n1": (3, 5), "n2": (5, 8)})
    rt = ClusterRuntime(cfg, params, p, ec, paged=True)
    assert not isinstance(rt.engines["n0"], PagedStageEngine)
    assert isinstance(rt.engines["n1"], PagedStageEngine)
    r2 = Request(0, prompt, max_new_tokens=6)
    rt.submit(r2)
    rt.run_until_done()
    assert r2.output == r1.output
    assert all(v == 0 for v in rt.pool_pages_used().values())


# --- scheduler feedback ------------------------------------------------------

def test_kv_estimator_sees_true_pool_occupancy(gqa_model):
    """The runtime must report real PagePool usage (and capacity) into the
    scheduler's KVEstimator — not arrival-time reservations."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    kv = rt.scheduler.kv
    for node, eng in rt.engines.items():
        assert kv.capacity_tokens[node] == eng.pool.tokens_capacity
    rt.submit(Request(0, np.arange(10) % cfg.vocab_size, max_new_tokens=8))
    for _ in range(4):
        rt.step()
    assert any(eng.pool.tokens_used > 0 for eng in rt.engines.values())
    for node, eng in rt.engines.items():
        assert kv.usage[node] == eng.pool.tokens_used
    rt.run_until_done()
    for node in rt.engines:
        assert kv.usage[node] == 0


# --- failover ----------------------------------------------------------------

def test_failover_replan_re_prefills_in_flight(gqa_model, reference):
    """Kill a stage node mid-decode: survivors release the victims' KV, the
    replanned placement is adopted, in-flight requests re-prefill (keeping
    generated tokens) and finish with unchanged outputs."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4), "n2": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    reqs = [Request(i, pr, max_new_tokens=6) for i, pr in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    for _ in range(6):
        rt.step()
    assert rt.jobs, "nothing in flight before the failure"
    rt.fail_node("n1")
    new = replan_after_failure(p, "n1", MILPOptions(time_limit_s=5.0,
                                                    lns_rounds=0,
                                                    fgls_rounds=10))
    rt.apply_plan(new)
    rt.run_until_done()
    assert [r.output for r in reqs] == ref
    assert "n1" not in rt.engines
    assert all(v == 0 for v in rt.pool_pages_used().values())


# --- guards ------------------------------------------------------------------

def test_runtime_rejects_oversized_prompt(gqa_model):
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True)
    with pytest.raises(ValueError, match="truncate"):
        rt.submit(Request(0, np.arange(EC.max_len + 1) % cfg.vocab_size))
    with pytest.raises(ValueError, match="empty"):
        rt.submit(Request(1, np.zeros((0,), np.int32)))


def test_stage_engine_holds_only_its_slice(gqa_model):
    cfg, params = gqa_model
    eng = PagedStageEngine(cfg, params, LayerRange(1, 3), EC)
    assert len(eng.sparams["blocks"]) == 2
    assert "embed" not in eng.sparams       # neither first nor last stage
    assert "final_norm" not in eng.sparams
    assert eng.pool.num_layers == 2         # pool priced at *local* layers
