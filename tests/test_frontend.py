"""Online front door: wall-clock ingest, SSE streaming, HTTP error
mapping, graceful drain — plus regression tests for the request-clock
bugs the front door exposed (mixed time.time()/time.monotonic() stamps,
idle-vs-stalled ambiguity in the serve loop, sampling × speculation).
"""
import json
import threading
import time
import urllib.error
import urllib.request
import queue as _queue

import numpy as np
import pytest

from repro.serving import ClusterRuntime, Frontend, InProcessTransport, Request

from harness import (EC, assert_pools_drained, draft_model, make_plan,
                     random_prompts)


# ---------------------------------------------------------------------------
# helpers


def _post(url, path, body, timeout=60):
    req = urllib.request.Request(
        url + path, data=json.dumps(body).encode("utf-8"),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, json.load(resp)


def _stream(url, body, timeout=60):
    """POST a streaming completion; returns (token_ids, output_indices,
    finish_reason)."""
    req = urllib.request.Request(
        url + "/v1/completions", data=json.dumps(body).encode("utf-8"),
        headers={"Content-Type": "application/json"})
    toks, idxs, finish = [], [], None
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        assert resp.headers["Content-Type"].startswith("text/event-stream")
        for raw in resp:
            line = raw.strip()
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):]
            if data == b"[DONE]":
                break
            choice = json.loads(data)["choices"][0]
            if choice.get("token_id") is not None:
                toks.append(choice["token_id"])
                idxs.append(choice["output_index"])
            if choice.get("finish_reason"):
                finish = choice["finish_reason"]
    return toks, idxs, finish


@pytest.fixture
def online_frontend(gqa_model):
    """A served 2-stage front door (wall clock over the in-process
    transport, pipelined decode window 2) + offline fixtures."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        realtime=True,
                        transport=InProcessTransport(default_delay_s=2e-3))
    fe = Frontend(rt, max_pending=8)
    host, port = fe.serve("127.0.0.1", 0)
    yield cfg, rt, fe, f"http://{host}:{port}"
    fe.shutdown(drain=True)
    rt.shutdown()
    assert fe.loop_error is None, f"runtime loop died: {fe.loop_error!r}"


# ---------------------------------------------------------------------------
# tentpole: wall-clock streaming ingest


def test_streamed_output_matches_offline_reference(online_frontend,
                                                   reference):
    """Requests submitted over HTTP while the loop is stepping (staggered,
    so later ones genuinely arrive mid-run) stream byte-identical greedy
    output to the single-engine offline reference, with SSE chunks in
    strict confirmation order across the max_inflight=2 window."""
    cfg, rt, fe, url = online_frontend
    prompts, refs = reference
    results = {}

    def fire(i):
        results[i] = _stream(url, {"prompt": [int(t) for t in prompts[i]],
                                   "max_tokens": 6, "stream": True})

    threads = []
    for i in range(len(prompts)):
        th = threading.Thread(target=fire, args=(i,), daemon=True)
        th.start()
        threads.append(th)
        time.sleep(0.03)        # arrivals land while earlier requests run
    for th in threads:
        th.join(timeout=120)
    assert sorted(results) == list(range(len(prompts)))
    for i, (toks, idxs, finish) in sorted(results.items()):
        assert toks == refs[i], (i, toks, refs[i])
        assert idxs == list(range(len(refs[i]))), idxs
        assert finish == "length"
    # wait for the loop to release slots (on_done fires before _release_all
    # finishes the last request's accounting is same-call; pending drains)
    deadline = time.monotonic() + 10
    while rt.pending() and time.monotonic() < deadline:
        time.sleep(0.02)
    assert_pools_drained(rt)
    s = fe.summary()
    assert s["requests"] == len(prompts)
    for key in ("ttft_s", "tpot_s", "e2e_s"):
        assert all(not (v < 0) for v in s[key].values()), s


def test_non_streaming_and_chat(online_frontend):
    cfg, rt, fe, url = online_frontend
    status, obj = _post(url, "/v1/completions",
                        {"prompt": "hello world", "max_tokens": 4})
    assert status == 200
    assert len(obj["choices"][0]["token_ids"]) == 4
    assert obj["usage"]["completion_tokens"] == 4
    status, obj = _post(url, "/v1/chat/completions",
                        {"messages": [{"role": "user", "content": "hi"}],
                         "max_tokens": 3})
    assert status == 200
    assert obj["choices"][0]["message"]["role"] == "assistant"
    assert obj["object"] == "chat.completion"


def test_models_and_healthz(online_frontend):
    cfg, rt, fe, url = online_frontend
    with urllib.request.urlopen(url + "/v1/models", timeout=30) as r:
        obj = json.load(r)
    assert obj["data"][0]["id"] == cfg.name
    with urllib.request.urlopen(url + "/healthz", timeout=30) as r:
        h = json.load(r)
    assert h["status"] == "ok"
    assert "queued=" in h["state"]          # _state() diagnostics surface


# ---------------------------------------------------------------------------
# HTTP error mapping


def test_http_400_mapping(online_frontend):
    cfg, rt, fe, url = online_frontend
    cases = [
        {"prompt": [0] * (EC.max_len + 10), "max_tokens": 2},  # over budget
        {"prompt": "", "max_tokens": 2},                  # empty
        {"prompt": [0, 1, cfg.vocab_size + 7], "max_tokens": 2},  # bad ids
        {"prompt": {"nested": 1}, "max_tokens": 2},       # wrong type
        {"messages": [], "max_tokens": 2, "_chat": True},  # empty chat
    ]
    for body in cases:
        path = "/v1/chat/completions" if body.pop("_chat", False) \
            else "/v1/completions"
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(url, path, body)
        assert ei.value.code == 400, body
        err = json.load(ei.value)["error"]
        assert err["message"], body


def test_http_429_at_capacity(gqa_model):
    """Past ``max_pending`` accepted-but-unfinished requests the server
    answers 429 with Retry-After instead of queueing without bound."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, realtime=True,
                        transport=InProcessTransport(default_delay_s=20e-3))
    fe = Frontend(rt, max_pending=1)
    host, port = fe.serve("127.0.0.1", 0)
    url = f"http://{host}:{port}"
    try:
        done = {}
        th = threading.Thread(
            target=lambda: done.setdefault(
                "r", _stream(url, {"prompt": [1] * 8, "max_tokens": 24,
                                   "stream": True}, timeout=120)),
            daemon=True)
        th.start()
        deadline = time.monotonic() + 30
        while rt.pending() == 0 and time.monotonic() < deadline:
            time.sleep(0.005)           # wait until the first is in flight
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(url, "/v1/completions", {"prompt": [2] * 8,
                                           "max_tokens": 2})
        assert ei.value.code == 429
        assert ei.value.headers["Retry-After"]
        th.join(timeout=120)
        assert done["r"][2] == "length"   # the in-flight stream finished
    finally:
        fe.shutdown(drain=True)
        rt.shutdown()


def test_graceful_drain(gqa_model):
    """During a drain new requests get 503 while the in-flight stream runs
    to completion; shutdown then stops the loop cleanly."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, realtime=True,
                        transport=InProcessTransport(default_delay_s=20e-3))
    fe = Frontend(rt)
    host, port = fe.serve("127.0.0.1", 0)
    url = f"http://{host}:{port}"
    done = {}
    th = threading.Thread(
        target=lambda: done.setdefault(
            "r", _stream(url, {"prompt": [3] * 8, "max_tokens": 16,
                               "stream": True}, timeout=120)),
        daemon=True)
    th.start()
    deadline = time.monotonic() + 30
    while rt.pending() == 0 and time.monotonic() < deadline:
        time.sleep(0.005)
    fe.begin_drain()                     # deterministic: 503 before shutdown
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(url, "/v1/completions", {"prompt": [4] * 8, "max_tokens": 2})
    assert ei.value.code == 503
    fe.shutdown(drain=True)
    th.join(timeout=120)
    toks, idxs, finish = done["r"]
    assert finish == "length" and len(toks) == 16
    assert fe.loop_error is None
    assert_pools_drained(rt)
    rt.shutdown()


# ---------------------------------------------------------------------------
# bugfix: cancel-on-disconnect


def test_mid_stream_disconnect_cancels_and_frees(gqa_model, reference):
    """A streaming client that slams its socket shut mid-generation must
    cancel the request in the runtime — ``cancelled_requests`` increments,
    KV pages free on every stage node — while another stream in flight
    finishes byte-identical to the offline reference."""
    import socket
    import struct

    cfg, params = gqa_model
    prompts, refs = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, max_inflight=2,
                        realtime=True,
                        transport=InProcessTransport(default_delay_s=5e-3))
    fe = Frontend(rt, max_pending=8)
    host, port = fe.serve("127.0.0.1", 0)
    url = f"http://{host}:{port}"
    try:
        done = {}
        th = threading.Thread(
            target=lambda: done.setdefault(
                "r", _stream(url, {"prompt": [int(t) for t in prompts[0]],
                                   "max_tokens": 6, "stream": True},
                             timeout=120)), daemon=True)
        th.start()
        # raw socket: long stream, read a couple of SSE chunks, then RST
        body = json.dumps({"prompt": [7] * 8, "max_tokens": 30,
                           "stream": True}).encode()
        s = socket.create_connection((host, port), timeout=60)
        s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: x\r\n"
                  b"Content-Type: application/json\r\n" +
                  f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        buf = b""
        while buf.count(b"data: ") < 2:     # tokens genuinely streamed
            chunk = s.recv(4096)
            assert chunk, "server closed the stream early"
            buf += chunk
        s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                     struct.pack("ii", 1, 0))    # RST on close
        s.close()
        # the handler notices on its next chunk write and cancels
        deadline = time.monotonic() + 60
        while rt.cancelled_requests == 0 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert rt.cancelled_requests == 1, "disconnect did not cancel"
        th.join(timeout=120)
        assert done["r"][0] == refs[0]      # survivor byte-identical
        assert done["r"][2] == "length"
        deadline = time.monotonic() + 10
        while rt.pending() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert_pools_drained(rt)            # no page leaked on any node
        with urllib.request.urlopen(url + "/healthz", timeout=30) as r:
            h = json.load(r)
        assert h["cancelled_requests"] == 1
        assert all(v == 0 for v in h["pool_pages_used"].values())
    finally:
        fe.shutdown(drain=True)
        rt.shutdown()
    assert fe.loop_error is None


# ---------------------------------------------------------------------------
# bugfix regressions: clock unification


def test_ttft_non_negative_under_wall_clock_step(gqa_model, monkeypatch):
    """Request stamps no longer mix time.time() with the monotonic event
    loop: even if NTP steps the wall clock backwards mid-request, TTFT,
    TPOT and E2E stay non-negative."""
    cfg, params = gqa_model
    # a wall clock that steps BACKWARDS by a minute on every read — the
    # worst NTP behaviour; any serving-path caller would go negative
    base = time.time()
    calls = [0]

    def broken_wall_clock():
        calls[0] += 1
        return base - 60.0 * calls[0]

    monkeypatch.setattr(time, "time", broken_wall_clock)
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True,
                        transport=InProcessTransport(default_delay_s=1e-3))
    reqs = [Request(i, pr, max_new_tokens=4)
            for i, pr in enumerate(random_prompts(cfg, (8, 6), seed=3))]
    for r in reqs:
        rt.submit(r)
    rt.run_until_done()
    for r in reqs:
        assert r.done
        # TTFT defined on virtual-clock runs too (first_token_s populated)
        assert r.first_token_s is not None
        assert r.submitted_s <= r.first_token_s <= r.finished_s
        assert r.first_token_s - r.submitted_s >= 0
        # the virtual clock actually advanced (link delays)
        assert r.finished_s > 0


def test_serving_paths_never_read_wall_clock():
    """Lint the clock-unification fix: no ``time.time()`` call may remain
    in the request-stamping serving modules (the runtime clock is
    monotonic-based; ``frontend`` uses time.time only for the cosmetic
    OpenAI ``created`` field)."""
    import inspect

    from repro.serving import engine, runtime
    for mod in (engine, runtime):
        src = inspect.getsource(mod)
        assert "time.time()" not in src, \
            f"{mod.__name__} reads the non-monotonic wall clock"


# ---------------------------------------------------------------------------
# bugfix regressions: idle vs stalled


def test_idle_server_does_not_stall(gqa_model):
    """An idle online server waiting for requests must NOT trip the stall
    timer; in-flight work still must (the timer is armed only over
    jobs/events)."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, realtime=True,
                        stall_timeout_s=0.3)
    err = []

    def loop():
        try:
            rt.serve_forever()
        except BaseException as e:
            err.append(e)

    th = threading.Thread(target=loop, daemon=True)
    th.start()
    time.sleep(1.0)              # idle for > 3x the stall budget
    assert th.is_alive() and not err, f"idle server stalled: {err}"
    got = _queue.Queue()
    req = Request(0, np.array([5, 6, 7], np.int32), max_new_tokens=3)
    rt.submit(req, on_done=lambda r: got.put(r))
    r = got.get(timeout=60)      # the sleeping loop wakes and serves it
    assert r is req and r.done and len(r.output) == 3
    rt.stop_serving()
    th.join(timeout=30)
    assert not th.is_alive() and not err, err


def test_stop_serving_exits_cleanly_when_idle(gqa_model):
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, realtime=True)
    th = threading.Thread(target=rt.serve_forever, daemon=True)
    th.start()
    time.sleep(0.1)
    rt.stop_serving()
    th.join(timeout=30)
    assert not th.is_alive()


# ---------------------------------------------------------------------------
# bugfix regressions: sampling x speculation


def test_temperature_rejected_with_draft(gqa_model):
    """temperature > 0 with a draft attached is an explicit error (greedy
    argmax verification would silently change the sampled distribution);
    greedy requests on the same runtime still serve, and the front door
    maps the rejection to HTTP 400."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    dcfg, dparams = draft_model(cfg, params)
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, realtime=True,
                        draft_cfg=dcfg, draft_params=dparams, spec_tokens=3)
    with pytest.raises(ValueError, match="speculative"):
        rt.submit(Request(0, np.array([1, 2, 3], np.int32),
                          max_new_tokens=2, temperature=0.8))
    fe = Frontend(rt)
    host, port = fe.serve("127.0.0.1", 0)
    url = f"http://{host}:{port}"
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(url, "/v1/completions",
                  {"prompt": [1, 2, 3], "max_tokens": 2,
                   "temperature": 0.8})
        assert ei.value.code == 400
        assert "speculative" in json.load(ei.value)["error"]["message"]
        # greedy still serves speculatively on the same runtime
        toks, idxs, finish = _stream(url, {"prompt": [1, 2, 3],
                                           "max_tokens": 4,
                                           "stream": True})
        assert len(toks) == 4 and finish == "length"
        assert rt.spec_rounds > 0
    finally:
        fe.shutdown(drain=True)
        rt.shutdown()


def test_temperature_plumbed_through_front_door(gqa_model):
    """Without a draft, per-request temperature reaches the runtime (the
    non-spec sampled path): temperature=0 is deterministic, and a sampled
    request still completes with the requested token budget."""
    cfg, params = gqa_model
    p = make_plan(cfg, {"n0": (0, 4)})
    rt = ClusterRuntime(cfg, params, p, EC, paged=True, realtime=True)
    fe = Frontend(rt)
    host, port = fe.serve("127.0.0.1", 0)
    url = f"http://{host}:{port}"
    try:
        a = _stream(url, {"prompt": [9] * 6, "max_tokens": 4,
                          "stream": True, "temperature": 0.0})
        b = _stream(url, {"prompt": [9] * 6, "max_tokens": 4,
                          "stream": True, "temperature": 0.0})
        assert a[0] == b[0]               # greedy is deterministic
        c = _stream(url, {"prompt": [9] * 6, "max_tokens": 4,
                          "stream": True, "temperature": 0.9})
        assert len(c[0]) == 4 and c[2] == "length"
    finally:
        fe.shutdown(drain=True)
        rt.shutdown()
