"""End-to-end multi-process serving (marked slow: spawns real worker
processes, each paying a JAX import + stage-program compile).

``ClusterRuntime.spawn_workers`` launches one ``repro.launch.worker``
subprocess per placed node; stage engines live in the workers, payloads
move over loopback TCP through the ``SocketTransport``, and the
coordinator keeps the whole control plane.  The anchors:

* greedy output across process boundaries is byte-identical to (a) the
  in-process runtime on the same plan and (b) the single full-model
  engine reference, at in-flight depths 1 and 2;
* every remote page pool drains to zero (checked over RPC);
* SIGKILLing a worker mid-decode is survivable: ``fail_node`` + replan +
  ``apply_plan`` re-prefills the in-flight requests on the survivors and
  finishes with unchanged outputs.
"""
import numpy as np
import pytest

from repro.core import MILPOptions, replan_after_failure
from repro.serving import ClusterRuntime, Request

from harness import (EC, assert_pools_drained, make_plan)

pytestmark = pytest.mark.slow


def _submit_all(rt, prompts, max_new_tokens=6):
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    return reqs


@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
def test_multiprocess_two_stage_matches_reference(gqa_model, reference,
                                                  max_inflight):
    cfg, params = gqa_model
    prompts, ref = reference
    prompts, ref = prompts[:2], ref[:2]
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime.spawn_workers(cfg, params, p, EC, paged=True,
                                      max_inflight=max_inflight,
                                      stall_timeout_s=120.0)
    try:
        assert len(rt.workers) == 2
        assert all(proc.poll() is None for proc in rt.workers.values())
        reqs = _submit_all(rt, prompts)
        rt.run_until_done()
        assert [r.output for r in reqs] == ref
        # pool drain is checked over RPC against the real remote pools
        used = rt.pool_pages_used()
        assert set(used) == {"n0", "n1"}
        assert_pools_drained(rt)
        # each request really crossed both processes
        for i in range(len(prompts)):
            assert len(rt.served[i].stages) == 2
    finally:
        rt.shutdown()
    assert not rt.workers                # shutdown reaped every process


def test_multiprocess_worker_kill_triggers_failover(gqa_model, reference):
    """SIGKILL a stage worker while decode passes are in flight; the
    coordinator must requeue the affected requests, adopt the replanned
    placement, re-prefill on the surviving workers, and finish with the
    reference outputs."""
    cfg, params = gqa_model
    prompts, ref = reference
    prompts, ref = prompts[:2], ref[:2]
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4), "n2": (0, 4)})
    rt = ClusterRuntime.spawn_workers(cfg, params, p, EC, paged=True,
                                      max_inflight=2,
                                      stall_timeout_s=120.0)
    try:
        reqs = _submit_all(rt, prompts)
        # run until decode is genuinely in flight somewhere
        for _ in range(2000):
            rt.step()
            if rt.jobs and any(len(r.output) > 0 for r in reqs):
                break
        assert rt.jobs, "nothing in flight before the kill"
        rt.kill_worker("n1")
        rt.fail_node("n1")
        new = replan_after_failure(p, "n1", MILPOptions(time_limit_s=5.0,
                                                        lns_rounds=0,
                                                        fgls_rounds=10))
        rt.apply_plan(new)
        rt.run_until_done()
        assert [r.output for r in reqs] == ref
        assert "n1" not in rt.engines and "n1" not in rt.workers
        assert_pools_drained(rt)
    finally:
        rt.shutdown()
