"""End-to-end multi-process serving (marked slow: spawns real worker
processes, each paying a JAX import + stage-program compile).

``ClusterRuntime.spawn_workers`` launches one ``repro.launch.worker``
subprocess per placed node; stage engines live in the workers, payloads
move over loopback TCP through the ``SocketTransport``, and the
coordinator keeps the whole control plane.  The anchors:

* greedy output across process boundaries is byte-identical to (a) the
  in-process runtime on the same plan and (b) the single full-model
  engine reference, at in-flight depths 1 and 2;
* every remote page pool drains to zero (checked over RPC);
* SIGKILLing a worker mid-decode is survivable: ``fail_node`` + replan +
  ``apply_plan`` re-prefills the in-flight requests on the survivors and
  finishes with unchanged outputs.
"""
import numpy as np
import pytest

from repro.core import MILPOptions, replan_after_failure
from repro.serving import ClusterRuntime, Request

from harness import (EC, assert_pools_drained, make_disagg_plan, make_plan)

pytestmark = pytest.mark.slow


def _submit_all(rt, prompts, max_new_tokens=6):
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        rt.submit(r)
    return reqs


@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
def test_multiprocess_two_stage_matches_reference(gqa_model, reference,
                                                  max_inflight):
    cfg, params = gqa_model
    prompts, ref = reference
    prompts, ref = prompts[:2], ref[:2]
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4)})
    rt = ClusterRuntime.spawn_workers(cfg, params, p, EC, paged=True,
                                      max_inflight=max_inflight,
                                      stall_timeout_s=120.0)
    try:
        assert len(rt.workers) == 2
        assert all(proc.poll() is None for proc in rt.workers.values())
        reqs = _submit_all(rt, prompts)
        rt.run_until_done()
        assert [r.output for r in reqs] == ref
        # pool drain is checked over RPC against the real remote pools
        used = rt.pool_pages_used()
        assert set(used) == {"n0", "n1"}
        assert_pools_drained(rt)
        # each request really crossed both processes
        for i in range(len(prompts)):
            assert len(rt.served[i].stages) == 2
    finally:
        rt.shutdown()
    assert not rt.workers                # shutdown reaped every process


@pytest.mark.parametrize("max_inflight", [1, 2], ids=["depth1", "depth2"])
def test_multiprocess_direct_links_matches_reference(gqa_model, reference,
                                                     max_inflight):
    """Routed worker-to-worker forwarding over real sockets: activations
    travel on peer links (counted per (src, dst) with real byte sizes),
    the coordinator sees only tokens, and output stays byte-identical."""
    cfg, params = gqa_model
    prompts, ref = reference
    prompts, ref = prompts[:2], ref[:2]
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    rt = ClusterRuntime.spawn_workers(cfg, params, p, EC, paged=True,
                                      max_inflight=max_inflight,
                                      stall_timeout_s=120.0,
                                      direct_links=True)
    try:
        reqs = _submit_all(rt, prompts)
        rt.run_until_done()
        assert [r.output for r in reqs] == ref
        assert_pools_drained(rt)
        tr = rt.transport
        # every decode pass forwarded both inter-stage frames peer-to-peer
        n_passes = sum(len(r) for r in ref)
        assert tr.transfers[("n0", "n1")] >= n_passes
        assert tr.transfers[("n1", "n2")] >= n_passes
        # peer frames are activations, not tokens: real bytes were counted
        assert tr.bytes_sent[("n0", "n1")] > \
            tr.transfers[("n0", "n1")] * rt.profile.token_bytes
        assert "hops[direct" in tr.describe()
    finally:
        rt.shutdown()


def test_multiprocess_disaggregated_survives_worker_kill(gqa_model,
                                                         reference):
    """The acceptance run: 1 prefill replica + decode replicas over real
    worker processes with direct links, byte-identical to the single-engine
    reference — including after SIGKILLing a decode worker mid-flight and
    adopting the replanned placement."""
    cfg, params = gqa_model
    prompts, ref = reference
    prompts, ref = prompts[:2], ref[:2]
    p = make_disagg_plan(cfg, {"n0": (0, 4)},
                         {"n1": (0, 2), "n2": (2, 4), "n3": (0, 4)})
    rt = ClusterRuntime.spawn_workers(cfg, params, p, EC, paged=True,
                                      max_inflight=2, stall_timeout_s=120.0,
                                      direct_links=True)
    try:
        assert rt.disaggregated
        reqs = _submit_all(rt, prompts)
        for _ in range(4000):
            rt.step()
            if rt.jobs and any(len(r.output) > 0 for r in reqs):
                break
        assert rt.jobs, "nothing in flight before the kill"
        rt.kill_worker("n1")
        rt.fail_node("n1")
        new = replan_after_failure(p, "n1", MILPOptions(time_limit_s=5.0,
                                                        lns_rounds=0,
                                                        fgls_rounds=10))
        rt.apply_plan(new)
        rt.run_until_done()
        assert [r.output for r in reqs] == ref
        assert "n1" not in rt.engines and "n1" not in rt.workers
        # KV handoffs really crossed process boundaries before the kill
        handoff = [k for k in rt.transport.transfers if k[0] == "n0"
                   and k[1] != "coordinator"]
        assert handoff, dict(rt.transport.transfers)
        assert_pools_drained(rt)
    finally:
        rt.shutdown()


def test_multiprocess_worker_kill_triggers_failover(gqa_model, reference):
    """SIGKILL a stage worker while decode passes are in flight; the
    coordinator must requeue the affected requests, adopt the replanned
    placement, re-prefill on the surviving workers, and finish with the
    reference outputs."""
    cfg, params = gqa_model
    prompts, ref = reference
    prompts, ref = prompts[:2], ref[:2]
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 4), "n2": (0, 4)})
    rt = ClusterRuntime.spawn_workers(cfg, params, p, EC, paged=True,
                                      max_inflight=2,
                                      stall_timeout_s=120.0)
    try:
        reqs = _submit_all(rt, prompts)
        # run until decode is genuinely in flight somewhere
        for _ in range(2000):
            rt.step()
            if rt.jobs and any(len(r.output) > 0 for r in reqs):
                break
        assert rt.jobs, "nothing in flight before the kill"
        rt.kill_worker("n1")
        rt.fail_node("n1")
        new = replan_after_failure(p, "n1", MILPOptions(time_limit_s=5.0,
                                                        lns_rounds=0,
                                                        fgls_rounds=10))
        rt.apply_plan(new)
        rt.run_until_done()
        assert [r.output for r in reqs] == ref
        assert "n1" not in rt.engines and "n1" not in rt.workers
        assert_pools_drained(rt)
    finally:
        rt.shutdown()
