"""MoE routing correctness: the scatter-free sort/gather dispatch must agree
with a straightforward dense reference, in values AND gradients (the
inverse_gather custom VJP is hand-written)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.common import init_params
from repro.models.moe import inverse_gather, moe_apply, moe_spec


def _dense_moe_ref(cfg, params, x, capacity_factor):
    """O(T*E) dense reference: every expert applied to every token, masked by
    top-k gates with first-come capacity dropping."""
    B, S, d = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # capacity mask (first-come order over flattened (t,k))
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32).reshape(T * K, E)
    pos = (jnp.cumsum(onehot, 0) - onehot)
    pos = (pos * onehot).sum(-1)
    C = max(1, int(T * K / E * capacity_factor))
    keep = (pos < C).reshape(T, K)
    gate_vals = jnp.where(keep, gate_vals, 0.0)
    # dense expert outputs
    from repro.models.common import silu
    hg = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    hu = jnp.einsum("td,edf->tef", xt, params["w_up"])
    out_e = jnp.einsum("tef,efd->ted", silu(hg) * hu, params["w_down"])
    full_gates = jnp.zeros((T, E), jnp.float32)
    tidx = jnp.arange(T)[:, None]
    full_gates = full_gates.at[tidx, expert_idx].add(gate_vals)
    y = jnp.einsum("te,ted->td", full_gates.astype(x.dtype), out_e)
    if cfg.moe_num_shared:
        from repro.models.moe import ffn_apply
        y = y + ffn_apply(params["shared"], xt)
    return y.reshape(B, S, d)


@pytest.mark.parametrize("arch", ["mixtral_8x22b", "deepseek_v2_236b"])
def test_moe_matches_dense_reference(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), moe_capacity_factor=8.0)
    params = init_params(moe_spec(cfg), jax.random.key(0), "float32")
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = moe_apply(cfg, params, x)
    ref = _dense_moe_ref(cfg, params, x, 8.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)
    assert float(aux["drop_frac"]) == 0.0


def test_moe_grad_matches_dense_reference():
    cfg = dataclasses.replace(get_smoke_config("mixtral_8x22b"),
                              moe_capacity_factor=8.0)
    params = init_params(moe_spec(cfg), jax.random.key(0), "float32")
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))

    g1 = jax.grad(lambda xx: moe_apply(cfg, params, xx)[0].sum())(x)
    g2 = jax.grad(lambda xx: _dense_moe_ref(cfg, params, xx, 8.0).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=5e-3, atol=5e-3)


def test_moe_grouped_matches_ungrouped():
    """Group-local dispatch == global dispatch when capacity is ample."""
    base = dataclasses.replace(get_smoke_config("mixtral_8x22b"),
                               moe_capacity_factor=8.0)
    grouped = dataclasses.replace(base, moe_groups=1)
    params = init_params(moe_spec(base), jax.random.key(0), "float32")
    x = jax.random.normal(jax.random.key(2), (4, 8, base.d_model))
    y0, _ = moe_apply(base, params, x)
    y1, _ = moe_apply(grouped, params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 16), st.integers(1, 8))
def test_inverse_gather_roundtrip(g, m, seed):
    """inverse_gather on a permutation: fwd == take_along_axis; custom bwd ==
    autodiff of take_along_axis."""
    key = jax.random.key(seed)
    x = jax.random.normal(key, (g, m, 4))
    perms = jnp.stack([jax.random.permutation(jax.random.key(seed + i), m)
                       for i in range(g)])
    inv = jnp.argsort(perms, axis=1)
    valid = jnp.ones((g, m), bool)

    out = inverse_gather(x, perms, inv, valid)
    ref = jnp.take_along_axis(x, perms[..., None], axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))

    g1 = jax.grad(lambda xx: (inverse_gather(xx, perms, inv, valid) ** 2).sum())(x)
    g2 = jax.grad(lambda xx: (jnp.take_along_axis(xx, perms[..., None], 1) ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6,
                               atol=1e-6)
