"""Distribution layer tests: sharding rules, pipeline parallelism vs
reference forward, Helix placement -> stage mapping, gradient compression.

Runs on CPU with a small forced device count (separate process would be
cleaner, but tests set XLA_FLAGS before the first jax import via conftest
ordering — see conftest.py)."""
import os

import numpy as np
import pytest

# must run before jax initializes a backend in this process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.configs.base import BlockSpec, ModelConfig
from repro.core.placement import LayerRange, Placement
from repro.dist import (SERVE_RULES, TRAIN_RULES, PipelineConfig,
                        compressed_psum, make_pipeline_loss,
                        pipeline_param_specs, sharding_for,
                        stage_units_from_placement)
from repro.models import forward, init, loss_fn
from repro.models.common import init_params, logical_axes


def need_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs {n} host devices, have {jax.device_count()}")


def test_sharding_rules_basic():
    need_devices(8)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    s = sharding_for((64, 16, 8), ("embed", "heads", "head_dim"),
                     TRAIN_RULES, mesh)
    assert s.spec == P("data", "model")
    # non-divisible dims fall back to replication (trailing Nones stripped)
    s = sharding_for((15, 30), ("heads", "embed"), TRAIN_RULES, mesh)
    assert len(s.spec) == 0 or s.spec[0] is None


def test_sharding_no_duplicate_axes():
    need_devices(8)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    s = sharding_for((8, 64, 32), ("experts", "embed", "ff"),
                     TRAIN_RULES, mesh)
    flat = []
    for e in s.spec:
        if isinstance(e, tuple):
            flat.extend(e)
        elif e is not None:
            flat.append(e)
    assert len(flat) == len(set(flat))


def test_compressed_psum_accuracy():
    need_devices(8)
    from jax.experimental.shard_map import shard_map
    import functools
    mesh = jax.make_mesh((8,), ("pod",))
    x = jax.random.normal(jax.random.key(0), (8, 128)) * 0.01

    @functools.partial(shard_map, mesh=mesh, in_specs=P("pod"),
                       out_specs=P("pod"), check_rep=False)
    def f(x):
        return compressed_psum(x[0], "pod")[None]

    out = f(x)
    expected = x.sum(axis=0)
    rel = np.abs(np.asarray(out[0]) - np.asarray(expected)).max() / (
        np.abs(np.asarray(expected)).max() + 1e-9)
    assert rel < 0.02, rel


def test_stage_units_from_placement():
    cfg = get_smoke_config("smollm_360m")          # pattern len 1, repeats 4
    placement = Placement({"n0": LayerRange(0, 3), "n1": LayerRange(3, 4)}, 4)
    units = stage_units_from_placement(placement, cfg, ["n0", "n1"])
    assert sum(units) == cfg.repeats
    assert units == [3, 1]


def _tiny_cfg():
    return ModelConfig(
        name="pipe-test", family="dense", d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=128,
        pattern=(BlockSpec(kind="attn", attn="full"),), repeats=4,
        norm="rmsnorm", tie_embeddings=True, param_dtype="float32",
        compute_dtype="float32")


def test_pipeline_loss_matches_reference():
    """Pipelined loss (2 stages x 4 data, unequal stages 3+1) must equal the
    single-program loss on identical params."""
    need_devices(8)
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((2, 4), ("stage", "data"))
    pipe = PipelineConfig(num_stages=2, stage_units=(3, 1),
                          num_microbatches=4)

    specs = pipeline_param_specs(cfg, pipe)
    params = init_params(specs, jax.random.key(0), "float32")

    # reference params: unroll stage-stacked blocks into the flat layer stack
    ref_params = init(cfg, jax.random.key(1))
    flat_layers = jax.tree.map(
        lambda x: jnp.concatenate(
            [x[0, :3], x[1, :1]], axis=0), params["super"])
    ref_params = dict(ref_params)
    ref_params["embed"] = params["embed"]
    ref_params["final_norm"] = params["final_norm"]
    ref_params["super"] = flat_layers

    tokens = jax.random.randint(jax.random.key(2), (16, 16), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}

    ref_loss, _ = loss_fn(cfg, ref_params, batch, aux_weight=0.0)

    pl = make_pipeline_loss(cfg, pipe, mesh)
    pipe_loss = pl(params, batch)
    np.testing.assert_allclose(np.asarray(pipe_loss), np.asarray(ref_loss),
                               rtol=2e-4, atol=2e-4)


def test_pipeline_grad_runs():
    need_devices(8)
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((2, 4), ("stage", "data"))
    pipe = PipelineConfig(num_stages=2, stage_units=(2, 2),
                          num_microbatches=2)
    specs = pipeline_param_specs(cfg, pipe)
    params = init_params(specs, jax.random.key(0), "float32")
    tokens = jax.random.randint(jax.random.key(2), (16, 8), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}
    pl = make_pipeline_loss(cfg, pipe, mesh)
    grads = jax.grad(lambda p: pl(p, batch))(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # embedding gradient must be nonzero (flows through first+last stage)
    assert float(jnp.abs(grads["embed"]).sum()) > 0
