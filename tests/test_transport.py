"""Transport chaos + wire-format tests.

Three layers of hardening for the PR-5 transport work:

1. **Chaos suite** — ``FlakyTransport`` is a Transport double that delays,
   reorders, duplicates, and drops-then-retransmits every payload on the
   runtime's virtual clock.  The differential anchor must hold anyway:
   greedy output byte-identical to a single full-model engine at in-flight
   depths 1-3, and every page pool drained to zero.  This pins down the
   runtime's delivery guards (dedup keys, per-stage chunk ordering, the
   coordinator inbox).

2. **Wire format** — round-trip property tests for
   ``encode_payload``/``decode_payload`` (bit-exact arrays across dtypes
   and ranks, nested trees) and the guarantee that malformed or truncated
   frames *raise* ``FrameError`` instead of hanging or mis-decoding.

3. **Backpressure** — a ``SocketTransport`` link to a worker that stops
   acking must block senders at the bounded queue (never buffer
   unboundedly), raise ``TransportStalled`` naming the link once the send
   timeout passes, and surface the stalled link through
   ``ClusterRuntime._state()`` diagnostics.
"""
import dataclasses
import socket
import threading
import time

import numpy as np
import pytest

from repro.serving import (ClusterRuntime, FrameError, InProcessTransport,
                           SocketTransport, StagedRef, TransportStalled,
                           WorkerChannel, decode_payload, encode_payload,
                           payload_bytes, recv_frame, send_frame)

from harness import (EC, assert_serves_like_reference, make_disagg_plan,
                     make_plan)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # only the property tests skip
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# chaos transport double
# ---------------------------------------------------------------------------

class FlakyTransport(InProcessTransport):
    """Delivers every payload at least once, but badly: random per-message
    jitter (reordering), random duplication, and random first-copy drops
    followed by a retransmission after a retry timeout.  Legal under the
    Transport contract ('send must eventually deliver'); never produced by
    the FIFO InProcessTransport."""

    def __init__(self, seed: int = 0, *, base_delay_s: float = 1e-3,
                 jitter_s: float = 4e-3, dup_p: float = 0.25,
                 drop_p: float = 0.25, retry_s: float = 8e-3):
        super().__init__(default_delay_s=base_delay_s)
        self._chaos_rng = np.random.RandomState(seed)
        self.jitter_s = jitter_s
        self.dup_p = dup_p
        self.drop_p = drop_p
        self.retry_s = retry_s
        self.duplicated = 0
        self.dropped = 0

    def send(self, src, dst, payload, nbytes, deliver):
        self.transfers[(src, dst)] += 1
        rng = self._chaos_rng
        d = self.delay(src, dst, nbytes) + rng.uniform(0.0, self.jitter_s)
        if rng.rand() < self.drop_p:
            # first copy lost on the wire; the link retransmits
            self.dropped += 1
            d += self.retry_s
        self._schedule(d, lambda: deliver(payload))
        if rng.rand() < self.dup_p:
            self.duplicated += 1
            self._schedule(d + rng.uniform(0.0, self.jitter_s),
                           lambda: deliver(payload))


# prompt_len=4 forces multi-chunk prefill (the session prompts are 5-16
# tokens), so chunk reordering across stage hops is actually exercised
CHAOS_EC = dataclasses.replace(EC, prompt_len=4)


@pytest.mark.parametrize("paged,depth",
                         [(True, 1), (True, 2), (True, 3), (False, 3)],
                         ids=["paged-d1", "paged-d2", "paged-d3",
                              "dense-d3"])
def test_chaos_transport_keeps_outputs_identical(gqa_model, reference,
                                                 paged, depth):
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    tr = FlakyTransport(seed=17 * depth + paged)
    assert_serves_like_reference(cfg, params, p, prompts, ref, paged=paged,
                                 max_inflight=depth, ec=CHAOS_EC,
                                 transport=tr)
    # the chaos must actually have happened for the run to mean anything
    assert tr.duplicated > 0 and tr.dropped > 0


@pytest.mark.parametrize("paged,depth", [(True, 1), (True, 2), (False, 2)],
                         ids=["paged-d1", "paged-d2", "dense-d2"])
def test_chaos_disaggregated_keeps_outputs_identical(gqa_model, reference,
                                                     paged, depth):
    """Chaos over the disaggregated dataflow: the prefill->decode KV
    handoff payloads are delayed, reordered, duplicated, and dropped (then
    retransmitted) along with everything else.  The handoff dedup key +
    the kv_pending launch gate must keep outputs byte-identical — a
    duplicated handoff may not double-import, a delayed one may not let
    decode start on an empty cache."""
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_disagg_plan(cfg, {"n0": (0, 4)}, {"n1": (0, 2), "n2": (2, 4)})
    tr = FlakyTransport(seed=29 * depth + paged)
    rt = assert_serves_like_reference(cfg, params, p, prompts, ref,
                                      paged=paged, max_inflight=depth,
                                      ec=CHAOS_EC, transport=tr)
    assert rt.disaggregated
    assert tr.duplicated > 0 and tr.dropped > 0
    assert tr.transfers[("n0", "n1")] >= len(prompts)   # handoffs happened


def test_chaos_transport_with_preemption(gqa_model, reference):
    """Chaos + a pool that only fits one full-budget request: preemption's
    epoch bumps and the delivery guards must compose (dedup state resets on
    readmission, stale duplicates die on the epoch check)."""
    from harness import (assert_pools_drained, pool_for_one_request,
                        serve_on_cluster)
    from repro.core import LayerRange
    cfg, params = gqa_model
    prompts, ref = reference
    p = make_plan(cfg, {"n0": (0, 2), "n1": (2, 3), "n2": (3, 4)})
    small = pool_for_one_request(cfg, LayerRange(2, 3), ec=CHAOS_EC)
    rt, reqs = serve_on_cluster(cfg, params, p, prompts, paged=True,
                                max_inflight=2, ec=CHAOS_EC,
                                pool_pages={"n1": small},
                                transport=FlakyTransport(seed=5))
    assert [r.output for r in reqs] == ref
    assert any(r.preemptions > 0 for r in reqs)
    assert_pools_drained(rt)


# ---------------------------------------------------------------------------
# wire format: fixed cases
# ---------------------------------------------------------------------------

def _roundtrip(obj):
    return decode_payload(payload_bytes(obj))


def test_wire_roundtrip_scalars_and_trees():
    cases = [
        None, True, False, 0, -1, 1 << 40, 3.5, float("inf"), "",
        "tøkens", b"\x00\xff", (), [], {},
        ("prefill_stage", [3, StagedRef(7), 0]),
        {"cfg": {"layers": (0, 4), "paged": True}, "xs": [1, 2.0, None]},
    ]
    for obj in cases:
        got = _roundtrip(obj)
        assert got == obj and type(got) is type(obj), obj
    # NaN compares unequal to itself
    assert np.isnan(_roundtrip(float("nan")))
    # numpy scalars normalize to python scalars
    assert _roundtrip(np.int32(-7)) == -7
    assert _roundtrip(np.float64(2.5)) == 2.5
    assert _roundtrip(np.bool_(True)) is True


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
@pytest.mark.parametrize("shape", [(), (0,), (5,), (3, 4), (2, 1, 4),
                                   (2, 3, 2, 2)])
def test_wire_roundtrip_arrays_bit_exact(dtype, shape):
    rng = np.random.RandomState(hash((dtype, shape)) % (1 << 31))
    arr = np.asarray(rng.standard_normal(size=shape)).astype(np.dtype(dtype)) \
        if dtype != "int32" \
        else rng.randint(-2**31, 2**31 - 1, size=shape, dtype=np.int32)
    got = _roundtrip(arr)
    assert got.dtype == arr.dtype and got.shape == arr.shape
    assert got.tobytes() == arr.tobytes()          # bit-exact, NaNs included


def test_wire_roundtrip_scratch_padded_batch():
    """The shapes the runtime actually ships: scratch-row-padded decode
    activations (max_batch+1, 1, d) in bf16 and a token chunk."""
    bf16 = np.dtype("bfloat16")
    h = np.random.RandomState(0).randn(EC.max_batch + 1, 1, 64).astype(bf16)
    toks = np.arange(13, dtype=np.int32)
    items = [(2, 17, 0, 0, h), (0, 3, 2, 441, None)]
    got = decode_payload(payload_bytes(("decode_stage", [items, toks])))
    m, (gi, gt) = got
    assert m == "decode_stage"
    assert gi[0][4].tobytes() == h.tobytes() and gi[0][4].dtype == bf16
    assert gi[1][4] is None
    assert np.array_equal(gt, toks)


def test_wire_normalizes_byte_order():
    """dtype names drop endianness, so a big-endian array must be swapped
    to the little-endian wire layout on encode — not silently reinterpreted
    on decode."""
    be = np.array([1.0, 2.0, -3.5], dtype=">f8")
    got = _roundtrip(be)
    assert np.array_equal(got, be.astype("<f8"))
    assert np.array_equal(_roundtrip(np.array([7, -9], dtype=">i4")),
                          np.array([7, -9], np.int32))


def test_wire_rejects_malformed():
    with pytest.raises(FrameError):
        decode_payload(b"")                        # no tag at all
    with pytest.raises(FrameError):
        decode_payload(b"Z")                       # unknown tag
    with pytest.raises(FrameError):
        decode_payload(payload_bytes(7) + b"x")    # trailing garbage
    with pytest.raises(FrameError):
        encode_payload(object())                   # unserializable
    # array whose header promises more bytes than shape*itemsize
    body = payload_bytes(np.zeros(4, np.float32))
    corrupt = bytearray(body)
    corrupt[-17] ^= 0xFF                           # flip a length byte
    with pytest.raises(FrameError):
        decode_payload(bytes(corrupt))


def test_wire_truncation_always_raises():
    payloads = [
        {"a": [1, 2.5, "x"], "b": np.arange(6, dtype=np.int32)},
        ("stage", [9, np.zeros((2, 3), np.dtype("bfloat16"))]),
        [None, True, b"bytes", StagedRef(3)],
    ]
    for obj in payloads:
        frame = payload_bytes(obj)
        for cut in range(len(frame)):
            with pytest.raises(FrameError):
                decode_payload(frame[:cut])


def test_frame_layer_rejects_bad_magic_and_truncation():
    a, b = socket.socketpair()
    try:
        a.sendall(b"GARBAGE!")                     # exactly one header
        with pytest.raises(FrameError, match="magic"):
            recv_frame(b)
    finally:
        a.close()
        b.close()
    # a peer that dies mid-frame raises instead of hanging
    a, b = socket.socketpair()
    try:
        send_frame(a, encode_payload(np.arange(100)))
        a.close()                                  # frame fully buffered...
        b2, c = socket.socketpair()
        try:
            # ...so replay only a prefix of it to a fresh reader
            whole = b.recv(1 << 16)
            b2.sendall(whole[:40])
            b2.close()
            with pytest.raises(FrameError, match="closed mid-frame"):
                recv_frame(c)
        finally:
            b2.close()
            c.close()
    finally:
        a.close()
        b.close()


def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        obj = {"h": np.random.RandomState(1).randn(2, 5).astype(np.float32),
               "meta": ("ok", [1, 2, 3])}
        send_frame(a, encode_payload(obj))
        got = decode_payload(recv_frame(b))
        assert got["meta"] == obj["meta"]
        assert np.array_equal(got["h"], obj["h"])
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# wire format: hypothesis round-trip property
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _dtypes = st.sampled_from(["float32", "bfloat16", "int32"])
    _shapes = st.lists(st.integers(0, 4), min_size=0, max_size=4)

    @st.composite
    def _arrays(draw):
        dtype = np.dtype(draw(_dtypes))
        shape = tuple(draw(_shapes))
        seed = draw(st.integers(0, 2**16))
        rng = np.random.RandomState(seed)
        if dtype.kind == "i":
            return rng.randint(-2**31, 2**31 - 1, size=shape,
                               dtype=np.int32)
        scale = 10.0 ** rng.randint(-3, 4)
        return np.asarray(rng.standard_normal(size=shape) * scale,
                          dtype=dtype)

    _leaves = st.one_of(
        st.none(), st.booleans(), st.integers(-2**62, 2**62), st.floats(
            allow_nan=False), st.text(max_size=20),
        st.binary(max_size=32),
        st.builds(StagedRef, st.integers(0, 2**40)), _arrays())

    _payloads = st.recursive(
        _leaves,
        lambda inner: st.one_of(
            st.lists(inner, max_size=4),
            st.lists(inner, max_size=4).map(tuple),
            st.dictionaries(st.text(max_size=8), inner, max_size=4)),
        max_leaves=12)

    def _eq(a, b):
        if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
            return (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                    and a.dtype == b.dtype and a.shape == b.shape
                    and a.tobytes() == b.tobytes())
        if isinstance(a, (list, tuple)):
            return (type(a) is type(b) and len(a) == len(b)
                    and all(_eq(x, y) for x, y in zip(a, b)))
        if isinstance(a, dict):
            return (isinstance(b, dict) and a.keys() == b.keys()
                    and all(_eq(a[k], b[k]) for k in a))
        return type(a) is type(b) and a == b

    @settings(max_examples=120, deadline=None)
    @given(obj=_payloads)
    def test_property_wire_roundtrip_bit_exact(obj):
        assert _eq(decode_payload(payload_bytes(obj)), obj)

    @settings(max_examples=60, deadline=None)
    @given(obj=_payloads, data=st.data())
    def test_property_truncated_frames_raise(obj, data):
        frame = payload_bytes(obj)
        cut = data.draw(st.integers(0, max(0, len(frame) - 1)),
                        label="cut")
        with pytest.raises(FrameError):
            decode_payload(frame[:cut])


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def _silent_worker_link(node="n9", *, queue_depth=2, send_timeout_s=0.5):
    """A SocketTransport wired to a 'worker' that never acks: the pump
    thread wedges in its first staging call and the bounded queue backs
    up."""
    a, b = socket.socketpair()
    ch = WorkerChannel(a, node=node, timeout_s=60.0)
    tr = SocketTransport({node: ch}, queue_depth=queue_depth,
                         send_timeout_s=send_timeout_s,
                         stalled_after_s=0.05)
    tr.bind(lambda d, fn: fn())
    return tr, b


def test_socket_backpressure_blocks_and_reports():
    tr, peer = _silent_worker_link()
    delivered = []
    payload = np.zeros(4096, np.float32)
    try:
        # first send wedges the pump in the unacked stage call; the next
        # queue_depth sends fill the bounded queue
        for _ in range(1 + tr.queue_depth):
            tr.send("c", "n9", payload, payload.nbytes, delivered.append)
        deadline = time.monotonic() + 5.0
        while ("c", "n9") not in tr._busy_since:
            assert time.monotonic() < deadline, "pump never started"
            time.sleep(0.01)
        # memory stays bounded at the queue depth
        assert tr._queues[("c", "n9")].qsize() <= tr.queue_depth
        t0 = time.monotonic()
        with pytest.raises(TransportStalled, match=r"c->n9"):
            tr.send("c", "n9", payload, payload.nbytes, delivered.append)
        # the sender genuinely blocked for the timeout before raising
        assert time.monotonic() - t0 >= tr.send_timeout_s * 0.9
        assert delivered == []                     # nothing faked through
        desc = tr.describe()
        assert "c->n9" in desc and "STALLED" in desc
    finally:
        tr.close()
        peer.close()


def test_runtime_state_reports_stalled_link(gqa_model):
    """run_until_done's stall diagnostics must name the wedged link: the
    _state() string carries the transport's per-link report."""
    cfg, params = gqa_model
    tr, peer = _silent_worker_link()
    try:
        p = make_plan(cfg, {"n0": (0, 4)})
        rt = ClusterRuntime(cfg, params, p, EC, paged=False, transport=tr,
                            stall_timeout_s=0.1)
        payload = np.zeros(16, np.float32)
        for _ in range(1 + tr.queue_depth):
            tr.send("c", "n9", payload, payload.nbytes, lambda x: None)
        deadline = time.monotonic() + 5.0
        while ("c", "n9") not in tr._busy_since:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        time.sleep(0.1)                            # exceed stalled_after_s
        state = rt._state()
        assert "c->n9" in state and "STALLED" in state
    finally:
        tr.close()
        peer.close()


def test_socket_transport_delivers_after_ack():
    """Happy path: a peer that acks staging frames gets payloads staged
    once and the runtime-side delivery is the StagedRef handle; scalars
    deliver by value."""
    a, b = socket.socketpair()
    ch = WorkerChannel(a, node="n0", timeout_s=10.0)
    tr = SocketTransport({"n0": ch}, queue_depth=4)
    got = []
    tr.bind(lambda d, fn: fn())
    staged = {}

    def fake_worker():
        while True:
            try:
                method, args = decode_payload(recv_frame(b))
            except FrameError:
                return
            assert method == "stage"
            staged[args[0]] = args[1]
            send_frame(b, encode_payload(("ok", None)))

    t = threading.Thread(target=fake_worker, daemon=True)
    t.start()
    try:
        arr = np.arange(12, dtype=np.float32).reshape(3, 4)
        tr.send("c", "n0", arr, arr.nbytes, got.append)
        tr.send("n0", "c", (3, 1234), 8.0, got.append)     # scalar: by value
        deadline = time.monotonic() + 10.0
        while len(got) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(got) == 2
        ref = next(g for g in got if isinstance(g, StagedRef))
        val = next(g for g in got if not isinstance(g, StagedRef))
        assert np.array_equal(staged[ref.tag], arr)
        assert val == (3, 1234)
    finally:
        tr.close()
        b.close()
