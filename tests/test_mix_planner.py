"""Cost/SLO-aware GPU-mix planner (Mélange-style): bucketed throughput
tables derived from the analytic ``ModelProfile``, mix feasibility via the
repo's own preflow-push max-flow, greedy solver (always available) vs the
ortools CP-SAT formulation (import-gated), and a cross-check of the
profiled rate against the event simulator so the table arithmetic cannot
silently drift from what the stack actually delivers."""
import dataclasses
import math

import pytest

from repro.core import LLAMA_70B, MILPOptions, plan
from repro.core.cluster import COORDINATOR, DEVICE_PROFILES
from repro.core.mix_planner import (SLO, Bucket, ThroughputTable,
                                    TrafficProfile, best_homogeneous,
                                    mix_is_feasible, solve_mix)

# the Mélange motivating shape: mostly short interactive traffic plus a
# long-prompt tail whose TTFT SLO only the big GPUs can meet
TRAFFIC = TrafficProfile(rate_rps=20.0,
                         buckets=[Bucket(64, 64), Bucket(1800, 128)],
                         weights=[0.9, 0.1])
SLO_STD = SLO(ttft_s=2.0, tpot_s=0.05)
DEVS = ("A100", "V100", "L4", "T4")


def test_throughput_table_arithmetic():
    """token_rate is min(compute, cap, nic) over the §3.2 model; SLO gating
    zeroes exactly the (device, bucket) pairs that miss TTFT/TPOT."""
    table = ThroughputTable.profile(LLAMA_70B, TRAFFIC.buckets, DEVS,
                                    slo=SLO_STD)
    for g in DEVS:
        d = DEVICE_PROFILES[g]
        want = min(d.flops / (LLAMA_70B.flops_per_token_layer
                              * LLAMA_70B.num_layers),
                   d.max_tokens_per_s,
                   d.nic_bytes_per_s / LLAMA_70B.activation_bytes)
        assert table.token_rate[g] == pytest.approx(want)
        assert table.max_layers[g] >= 1      # every type fits some slice
    # the short bucket is feasible on every type (TPOT and tiny prefill)
    assert all(table.rates[g][0] > 0 for g in DEVS)
    # the long-prompt bucket's 2 s TTFT needs 1800/(2*T) <= 2 -> T >= 450:
    # only the A100 row survives
    long_ok = {g for g in DEVS if table.rates[g][1] > 0}
    assert long_ok == {"A100"}
    # a feasible rate is tokens/s over the bucket's request cost
    assert table.rates["A100"][1] == pytest.approx(
        table.token_rate["A100"] / TRAFFIC.buckets[1].tokens)


def test_mix_meets_rate_and_beats_homogeneous():
    """The tentpole assertion: the solved mix serves the target rate at
    STRICTLY lower $/hr than the best homogeneous cluster, by pairing the
    expensive type (bought only for the long-prompt tail) with cheap types
    absorbing the short bucket."""
    mix = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD)
    homo = best_homogeneous(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD)
    assert homo is not None
    assert mix.predicted_rate_rps >= TRAFFIC.rate_rps
    assert mix.cost_per_hour < homo.cost_per_hour
    assert len(mix.counts) >= 2          # genuinely heterogeneous
    assert "A100" in mix.counts          # the only type serving the tail
    assert mix_is_feasible(mix.table, TRAFFIC, mix.counts)
    # trim left nothing redundant: dropping any node breaks feasibility
    for g in mix.counts:
        fewer = dict(mix.counts)
        fewer[g] -= 1
        assert not mix_is_feasible(mix.table, TRAFFIC, fewer), \
            f"mix still feasible without one {g} — trim missed it"
    # homogeneous is single-type and itself feasible
    assert len(homo.counts) == 1
    assert homo.predicted_rate_rps >= TRAFFIC.rate_rps


def test_mix_cluster_materializes_with_costs():
    """The mix is an ordinary ClusterSpec: node count, per-node device
    profiles, and summed $/hr all match the solved plan."""
    mix = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD)
    cluster = mix.cluster()
    names = [n for n in cluster.nodes if n != COORDINATOR]
    assert len(names) == mix.num_nodes
    assert cluster.cost_per_hour() == pytest.approx(mix.cost_per_hour)
    for g, n in mix.counts.items():
        assert sum(1 for name in names
                   if cluster.nodes[name].device.name == g) == n
    # full mesh: every ordered worker pair has a link
    assert all((a, b) in cluster.links
               for a in names for b in names if a != b)


def test_from_requests_buckets_observed_lengths():
    """Live-stats bucketing: centers are the member means (what was seen,
    not bin midpoints) and weights are the member fractions."""
    pairs = [(60, 60)] * 45 + [(70, 70)] * 45 + [(1800, 128)] * 10
    t = TrafficProfile.from_requests(pairs, rate_rps=5.0)
    assert t.rate_rps == 5.0
    assert sum(t.weights) == pytest.approx(1.0)
    assert len(t.buckets) == 2
    short, long_ = sorted(zip(t.buckets, t.weights),
                          key=lambda bw: bw[0].input_len)
    assert short[0] == Bucket(65, 65)    # mean of 60s and 70s
    assert short[1] == pytest.approx(0.9)
    assert long_[0] == Bucket(1800, 128)
    assert long_[1] == pytest.approx(0.1)


def test_headroom_overprovisions():
    mix1 = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD)
    mix2 = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD, headroom=1.5)
    assert mix2.cost_per_hour >= mix1.cost_per_hour
    assert mix2.predicted_rate_rps >= 1.5 * TRAFFIC.rate_rps * (1 - 1e-6)


def test_unservable_bucket_raises():
    """A bucket no device type can meet must be an explicit error, not a
    silently-undersized mix."""
    harsh = SLO(ttft_s=0.2, tpot_s=0.05)   # 1800-token prefill in 200 ms
    with pytest.raises(ValueError, match="no device type"):
        solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=harsh, solver="greedy")
    assert best_homogeneous(LLAMA_70B, TRAFFIC, DEVS, slo=harsh) is None


def test_cpsat_gate():
    """solver="cpsat" must raise a clear error when ortools is absent (the
    container does not ship it); "auto" must still solve via greedy."""
    try:
        import ortools  # noqa: F401
        have = True
    except ImportError:
        have = False
    if have:
        mix = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD,
                        solver="cpsat")
        greedy = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD,
                           solver="greedy")
        assert mix_is_feasible(mix.table, TRAFFIC, mix.counts)
        # CP-SAT is exact over the same model: never beaten by greedy
        assert mix.cost_per_hour <= greedy.cost_per_hour + 1e-9
    else:
        with pytest.raises(RuntimeError, match="ortools"):
            solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD, solver="cpsat")
    auto = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD, solver="auto")
    assert auto.solver in ("greedy", "cpsat")
    assert mix_is_feasible(auto.table, TRAFFIC, auto.counts)


def test_profiled_rate_holds_in_simulator():
    """The profiler-vs-simulator check the table docstring promises: a
    homogeneous cluster driven at ~70% of its profiled max rate completes
    the whole trace in the event simulator with zero drops."""
    from repro.sim import Simulator
    from repro.sim.traces import TraceRequest

    traffic = TrafficProfile(rate_rps=8.0, buckets=[Bucket(64, 64)],
                             weights=[1.0])
    homo = best_homogeneous(LLAMA_70B, traffic, ("A100",), slo=SLO_STD)
    assert homo is not None
    cluster = homo.cluster()
    p = plan(cluster, LLAMA_70B, MILPOptions(time_limit_s=5.0, lns_rounds=0,
                                             fgls_rounds=10))
    rate = 0.7 * homo.predicted_rate_rps
    assert rate > 0 and math.isfinite(rate)
    trace = [TraceRequest(i, (i + 1) / rate, 64, 64) for i in range(50)]
    sim = Simulator(cluster, LLAMA_70B, p.placement, p.make_scheduler(),
                    warmup_s=2.0, horizon_s=300.0, decode_chunk=4)
    m = sim.run(trace)
    assert m.dropped_requests == 0
    assert m.completed_requests == len(trace)
    # cost metrics thread through: Metrics carries the cluster's $/hr
    assert m.cost_per_hour == pytest.approx(cluster.cost_per_hour())
    assert m.dollars_per_million_tokens > 0


def test_predicted_rate_is_tight():
    """predicted_rate_rps is the feasibility boundary: the mix serves at
    that rate but not at 5% above it."""
    mix = solve_mix(LLAMA_70B, TRAFFIC, DEVS, slo=SLO_STD)
    at = dataclasses.replace(TRAFFIC, rate_rps=mix.predicted_rate_rps * 0.999,
                             weights=list(TRAFFIC.weights))
    over = dataclasses.replace(TRAFFIC, rate_rps=mix.predicted_rate_rps * 1.05,
                               weights=list(TRAFFIC.weights))
    assert mix_is_feasible(mix.table, at, mix.counts)
    assert not mix_is_feasible(mix.table, over, mix.counts)
