"""Placement -> pipeline-stage mapping and IWRR proportionality.

Pure-Python coverage of the Helix glue: MILP layer ranges becoming unequal
GPipe stage sizes (repro.dist.pipeline.stage_units_from_placement) and the
flow-weighted interleaved round-robin the runtime scheduler picks next hops
with (repro.core.scheduler.IWRR).  No devices needed.
"""
import collections

import pytest

from repro.configs import get_smoke_config
from repro.core.placement import LayerRange, Placement
from repro.core.scheduler import IWRR
from repro.dist.pipeline import PipelineConfig, stage_units_from_placement


def test_uneven_placement():
    cfg = get_smoke_config("smollm_360m")          # pattern len 1, repeats 4
    placement = Placement({"big": LayerRange(0, 3),
                           "small": LayerRange(3, 4)}, 4)
    assert stage_units_from_placement(placement, cfg,
                                      ["big", "small"]) == [3, 1]


def test_raw_layer_placement_pattern_len_1():
    """mixtral: pattern length 1, so raw layers == super-block units."""
    cfg = get_smoke_config("mixtral_8x22b")        # pattern len 1, repeats 4
    placement = Placement({"a": LayerRange(0, 1), "b": LayerRange(1, 4)}, 4)
    assert stage_units_from_placement(placement, cfg, ["a", "b"]) == [1, 3]


def test_raw_layer_placement_pattern_len_gt_1():
    """jamba smoke: 4-block super-pattern x 2 repeats = 8 raw layers; the
    planner's raw-layer ranges collapse to super-block stage units."""
    cfg = get_smoke_config("jamba_1_5_large_398b")
    assert len(cfg.pattern) == 4 and cfg.repeats == 2
    placement = Placement({"a": LayerRange(0, 4), "b": LayerRange(4, 8)}, 8)
    assert stage_units_from_placement(placement, cfg, ["a", "b"]) == [1, 1]
    # a boundary inside a super-block is not pipelineable
    bad = Placement({"a": LayerRange(0, 3), "b": LayerRange(3, 8)}, 8)
    with pytest.raises(ValueError, match="super-block"):
        stage_units_from_placement(bad, cfg, ["a", "b"])


def test_single_node_degenerates_to_one_stage():
    cfg = get_smoke_config("smollm_360m")
    placement = Placement({"solo": LayerRange(0, 4)}, 4)
    units = stage_units_from_placement(placement, cfg, ["solo"])
    assert units == [cfg.repeats]
    pipe = PipelineConfig(num_stages=1, stage_units=tuple(units),
                          num_microbatches=2)
    assert pipe.max_units == cfg.repeats


def test_replicated_node_uses_partial_inference():
    """A node fully covered by its predecessors contributes no stage; a
    partially overlapping one contributes only the uncovered tail (§3.3)."""
    cfg = get_smoke_config("smollm_360m")
    placement = Placement({"a": LayerRange(0, 3), "dup": LayerRange(1, 3),
                           "b": LayerRange(2, 4)}, 4)
    assert stage_units_from_placement(placement, cfg,
                                      ["a", "dup", "b"]) == [3, 1]


def test_gap_raises():
    cfg = get_smoke_config("smollm_360m")
    placement = Placement({"a": LayerRange(0, 2), "b": LayerRange(3, 4)}, 4)
    with pytest.raises(ValueError, match="gap"):
        stage_units_from_placement(placement, cfg, ["a", "b"])


def test_iwrr_proportional_within_one():
    """Smooth IWRR: in every window of sum(weights) picks, each candidate is
    chosen weight +/- 1 times (flow-proportional routing without bursts)."""
    weights = {"a": 5.0, "b": 3.0, "c": 2.0}
    it = IWRR(list(weights), list(weights.values()))
    window = int(sum(weights.values()))
    picks = [it.pick() for _ in range(100 * window)]
    assert None not in picks
    for i in range(0, len(picks), window):
        counts = collections.Counter(picks[i:i + window])
        for cand, w in weights.items():
            assert abs(counts[cand] - w) <= 1, (i, counts)
