"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig6/7   single-cluster serving (offline+online, 30B/70B)
  fig8/9   distributed-cluster serving
  fig9e    42-node high-heterogeneity
  fig10    placement deep dive (helix/petals/swarm placements)
  fig11    scheduling deep dive (helix/swarm/random scheduling)
  fig12a+tab4  cluster-pruning ablation
  fig12b   warm-start ablation
  fault_*  beyond-paper fault tolerance (failover, straggler)
  pipelined_decode  in-flight decode window depth 1 vs 2 (latency)
  online_latency    front-door latency under open-loop load (TTFT/TPOT/SLO)
  gpu_mix           cost/SLO-aware GPU-mix planning vs best homogeneous

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig10,...]
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = {}


def _register():
    from .ablation_tables import bench_ablation_pruning, bench_ablation_warmstart
    from .fault_tables import bench_failover, bench_straggler
    from .mix_tables import bench_gpu_mix
    from .placement_tables import bench_placement_deepdive
    from .scheduling_tables import bench_scheduling_deepdive
    from .serving_tables import (bench_direct_links,
                                 bench_distributed_cluster,
                                 bench_high_heterogeneity,
                                 bench_kv_quant,
                                 bench_online_latency,
                                 bench_pipelined_decode,
                                 bench_single_cluster,
                                 bench_spec_decode)
    BENCHES.update({
        "fig6_single_cluster": bench_single_cluster,
        "fig8_distributed": bench_distributed_cluster,
        "fig9e_heterogeneity": bench_high_heterogeneity,
        "pipelined_decode": bench_pipelined_decode,
        "kv_quant": bench_kv_quant,
        "direct_links": bench_direct_links,
        "spec_decode": bench_spec_decode,
        "online_latency": bench_online_latency,
        "gpu_mix": bench_gpu_mix,
        "fig10_placement": bench_placement_deepdive,
        "fig11_scheduling": bench_scheduling_deepdive,
        "fig12a_pruning": bench_ablation_pruning,
        "fig12b_warmstart": bench_ablation_warmstart,
        "fault_failover": bench_failover,
        "fault_straggler": bench_straggler,
    })


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="smaller traces / budgets")
    p.add_argument("--only", default="",
                   help="comma-separated bench keys (default: all)")
    args = p.parse_args()
    _register()
    keys = [k for k in args.only.split(",") if k] or list(BENCHES)
    print("name,us_per_call,derived", flush=True)
    failures = 0
    for key in keys:
        t0 = time.time()
        try:
            BENCHES[key](quick=args.quick)
            print(f"{key}__total,{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception as e:  # keep the harness going
            failures += 1
            import traceback
            traceback.print_exc(file=sys.stderr)
            print(f"{key}__total,{(time.time() - t0) * 1e6:.0f},FAILED:{e}",
                  flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
