"""Beyond-paper: fault-tolerance benchmarks.

Node failure mid-run with elastic replanning, and straggler mitigation via
flow reweighting — throughput retained vs a no-mitigation run.
"""
from __future__ import annotations

from repro.core import (LLAMA_70B, MILPOptions, make_single_cluster, plan,
                        replan_after_failure, reweight_for_straggler)
from repro.sim import Simulator, make_offline_trace

from .common import FAST_MILP, emit


def bench_failover(quick: bool = False):
    cluster = make_single_cluster()
    p = plan(cluster, LLAMA_70B, FAST_MILP)
    n_req = 200 if quick else 400

    def run(with_replan: bool):
        pp = plan(cluster, LLAMA_70B, placement=p.placement)
        sched = pp.make_scheduler()
        state = {"plan": pp}

        def replan(dead):
            new = replan_after_failure(
                state["plan"], dead,
                MILPOptions(time_limit_s=8.0, lns_rounds=0, fgls_rounds=30))
            state["plan"] = new
            return new.make_scheduler(), new.placement

        sim = Simulator(cluster, LLAMA_70B, pp.placement, sched,
                        warmup_s=10.0, horizon_s=240.0, decode_chunk=4,
                        replan_fn=replan if with_replan else None)
        # kill the strongest node mid-run
        victim = max(pp.placement.assignment,
                     key=lambda n: cluster.nodes[n].flops)
        sim.fail_node(60.0, victim)
        return sim.run(make_offline_trace(n_req, seed=5))

    m_replan = run(True)
    m_none = run(False)
    emit("fault_failover_with_replan_tps", 0.0,
         f"{m_replan.decode_throughput:.1f}")
    emit("fault_failover_no_replan_tps", 0.0,
         f"{m_none.decode_throughput:.1f}")
    emit("fault_failover_restarts", 0.0, m_replan.restarts)
    return m_replan, m_none


def bench_straggler(quick: bool = False):
    cluster = make_single_cluster()
    p = plan(cluster, LLAMA_70B, FAST_MILP)
    n_req = 200 if quick else 400
    victim = max(p.placement.assignment,
                 key=lambda n: cluster.nodes[n].flops)

    def run(mitigate: bool):
        pp = plan(cluster, LLAMA_70B, placement=p.placement)
        sched = pp.make_scheduler()
        sim = Simulator(cluster, LLAMA_70B, pp.placement, sched,
                        warmup_s=10.0, horizon_s=240.0, decode_chunk=4)
        sim.slow_node(30.0, victim, 0.15)
        if mitigate:
            # detection: reweight flows on the degraded graph at t=60
            degraded = reweight_for_straggler(pp, victim, 0.15)
            sim._push(60.0, lambda: sched.update_weights(degraded.flows))
        return sim.run(make_offline_trace(n_req, seed=6))

    m_yes = run(True)
    m_no = run(False)
    emit("fault_straggler_mitigated_tps", 0.0,
         f"{m_yes.decode_throughput:.1f}")
    emit("fault_straggler_unmitigated_tps", 0.0,
         f"{m_no.decode_throughput:.1f}")
    return m_yes, m_no
