"""Paper Fig. 12 + Table 4: MILP optimization ablations.

(a) cluster pruning: problem size (vars/constraints) and resulting
    throughput, 24-node and 42-node settings;
(b) warm start: solver path with vs without heuristic incumbents
    (LNS fix-and-reoptimize reproduces Gurobi's `Start` hint — §3.4 /
    DESIGN.md substitutions).
"""
from __future__ import annotations

import time

from repro.core import (LLAMA_70B, MILPOptions, make_high_heterogeneity_cluster,
                        make_single_cluster, solve_placement)
from repro.core.milp import _build_problem

from .common import emit


def bench_ablation_pruning(quick: bool = False):
    out = {}
    budget = 10.0 if quick else 25.0
    for cname, cluster in [("24node", make_single_cluster()),
                           ("42node", make_high_heterogeneity_cluster())]:
        for prune in (12, None):
            opts = MILPOptions(time_limit_s=budget, lns_rounds=1,
                               lns_time_limit_s=budget / 3,
                               prune_degree=prune, fgls_rounds=40)
            prob = _build_problem(cluster, LLAMA_70B, opts)
            t0 = time.time()
            res = solve_placement(cluster, LLAMA_70B, opts)
            wall = time.time() - t0
            label = "pruned" if prune else "full"
            emit(f"tab4_{cname}_{label}_vars", wall, len(prob.reg))
            emit(f"tab4_{cname}_{label}_constraints", wall,
                 len(prob.cons.rows))
            emit(f"fig12a_{cname}_{label}_tput", wall,
                 f"{res.actual_throughput:.1f}")
            out[(cname, label)] = (len(prob.reg), len(prob.cons.rows),
                                   res.actual_throughput)
    return out


def bench_ablation_warmstart(quick: bool = False):
    """Cold MILP vs heuristic-seeded (incumbent + LNS) under equal budget."""
    out = {}
    budget = 12.0 if quick else 30.0
    for cname, cluster in [("24node", make_single_cluster())] + (
            [] if quick else [("42node", make_high_heterogeneity_cluster())]):
        t0 = time.time()
        cold = solve_placement(cluster, LLAMA_70B, MILPOptions(
            time_limit_s=budget, warm_start=False, lns_rounds=0,
            fgls_rounds=0))
        cold_tput = max((h["throughput"] for h in cold.meta["history"]
                         if h["phase"] == "milp"), default=0.0)
        cold_wall = time.time() - t0
        t0 = time.time()
        warm = solve_placement(cluster, LLAMA_70B, MILPOptions(
            time_limit_s=budget / 2, lns_rounds=2,
            lns_time_limit_s=budget / 4, fgls_rounds=40))
        warm_wall = time.time() - t0
        emit(f"fig12b_{cname}_cold_milp_tput", cold_wall,
             f"{cold_tput:.1f}")
        emit(f"fig12b_{cname}_warm_tput", warm_wall,
             f"{warm.actual_throughput:.1f}")
        out[cname] = (cold_tput, warm.actual_throughput)
    return out
