"""Shared benchmark plumbing: method construction + simulated serving runs.

Methods under comparison (paper §5.2-§5.7):
  helix   — MILP/FGLS placement + max-flow IWRR scheduling (+KV estimation)
  swarm   — equal-stage placement + throughput-proportional routing
  sp      — separate homogeneous pipelines (+ mixed tail for SP+)
  petals  — greedy least-covered placement (placement deep-dive only)
  random  — random next-hop scheduling (scheduling deep-dive only)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

from repro.core import (COORDINATOR, ClusterSpec, MILPOptions, ModelProfile,
                        Placement, RandomScheduler, SwarmScheduler,
                        petals_placement, placement_throughput, plan,
                        separate_pipelines_placement, solve_placement,
                        swarm_placement)
from repro.core.scheduler import HelixScheduler, KVEstimator
from repro.sim import Simulator, make_offline_trace, make_trace
from repro.sim.traces import online_rate_for_cluster

FAST_MILP = MILPOptions(time_limit_s=15.0, lns_rounds=2, lns_time_limit_s=6.0,
                        fgls_rounds=50, mip_rel_gap=0.05)


def make_placement(method: str, cluster: ClusterSpec, model: ModelProfile,
                   opts: Optional[MILPOptions] = None) -> Placement:
    opts = opts or FAST_MILP
    if method == "helix":
        return solve_placement(cluster, model, opts).placement
    if method == "swarm":
        return swarm_placement(cluster, model)
    if method == "petals":
        return petals_placement(cluster, model)
    if method == "sp":
        return separate_pipelines_placement(cluster, model)
    if method == "sp+":
        return separate_pipelines_placement(cluster, model,
                                            allow_mixed_tail=True)
    raise ValueError(method)


def make_scheduler(method: str, cluster, model, placement, flows,
                   seed: int = 0):
    kv = KVEstimator.from_placement(cluster, model, placement)
    if method == "helix":
        return HelixScheduler(cluster, model, placement, flows,
                              kv_estimator=kv)
    if method == "swarm":
        return SwarmScheduler(cluster, model, placement, seed=seed)
    if method == "random":
        return RandomScheduler(cluster, model, placement, seed=seed)
    raise ValueError(method)


@dataclasses.dataclass
class ServingResult:
    method: str
    decode_throughput: float
    processed_throughput: float
    prompt_latency: Dict[str, float]
    decode_latency: Dict[str, float]
    flow_bound: float
    wall_s: float


def run_serving(cluster: ClusterSpec, model: ModelProfile,
                placement_method: str, scheduler_method: str,
                *, offline: bool = True, num_requests: int = 400,
                horizon_s: float = 240.0, warmup_s: float = 10.0,
                seed: int = 0, decode_chunk: int = 4,
                placement: Optional[Placement] = None,
                opts: Optional[MILPOptions] = None) -> ServingResult:
    t0 = time.time()
    if placement is None:
        placement = make_placement(placement_method, cluster, model, opts)
    p = plan(cluster, model, placement=placement)
    sched = make_scheduler(scheduler_method, cluster, model, placement,
                           p.flows, seed=seed)
    if offline:
        trace = make_offline_trace(num_requests, seed=seed)
    else:
        rate = online_rate_for_cluster(p.throughput, utilization=0.75)
        trace = make_trace(num_requests, arrival_rate_per_s=max(rate, 0.2),
                           seed=seed)
    sim = Simulator(cluster, model, placement, sched, warmup_s=warmup_s,
                    horizon_s=horizon_s, decode_chunk=decode_chunk)
    m = sim.run(trace)
    return ServingResult(
        method=f"{placement_method}/{scheduler_method}",
        decode_throughput=m.decode_throughput,
        processed_throughput=m.processed_throughput,
        prompt_latency=m.prompt_latency,
        decode_latency=m.decode_latency,
        flow_bound=p.throughput,
        wall_s=time.time() - t0)


def emit(name: str, wall_s: float, derived) -> None:
    """CSV row per bench: name,us_per_call,derived."""
    print(f"{name},{wall_s * 1e6:.0f},{derived}", flush=True)
