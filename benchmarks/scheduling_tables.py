"""Paper Fig. 11: request scheduling deep dive.

Isolates scheduling from placement: Helix's placement everywhere; compare
Helix IWRR vs Swarm (throughput-proportional) vs random scheduling,
LLaMA-70B offline, single and distributed clusters.  Also reports per-link
queueing (the §5.7 congestion case study).
"""
from __future__ import annotations

from repro.core import (LLAMA_70B, make_distributed_cluster,
                        make_single_cluster)

from .common import emit, make_placement, run_serving


def bench_scheduling_deepdive(quick: bool = False):
    out = {}
    n_req = 150 if quick else 300
    for cname, cluster in [("single", make_single_cluster()),
                           ("dist", make_distributed_cluster())]:
        placement = make_placement("helix", cluster, LLAMA_70B)
        rows = {}
        for sm in ("helix", "swarm", "random"):
            r = run_serving(cluster, LLAMA_70B, "helix", sm, offline=True,
                            num_requests=n_req, placement=placement)
            rows[sm] = r
            emit(f"fig11_{cname}_{sm}_decode_tps", r.wall_s,
                 f"{r.decode_throughput:.1f}")
        for other in ("swarm", "random"):
            gain = rows["helix"].decode_throughput / max(
                rows[other].decode_throughput, 1e-9)
            emit(f"fig11_{cname}_helix_vs_{other}_gain", 0.0, f"{gain:.3f}")
        out[cname] = rows
    return out
